"""Vector-clock causal broadcast — the paper's Table 1 baseline.

Classic Fidge/Mattern causality tracking over a gossip overlay: every
broadcast message piggybacks the sender's full vector clock (O(N) control
bytes, N = processes that ever broadcast); receivers delay out-of-order
messages in a pending set and re-scan it after every delivery — the
O(W·N) delivery execution time Table 1 charges this family with.

Unlike PC-broadcast it needs neither FIFO links nor link-safety gating, so
it tolerates dynamic overlays out of the box — at the price of overhead
that grows with the fleet.  ``comparisons`` counts vector-entry comparisons
so benchmarks can expose the W·N behaviour directly.

Method map (classic causal broadcast, the family Table 1's first row
summarizes; there is no paper algorithm listing for the baseline):

  ``broadcast``             stamp the message with the local clock
                            (sender entry pre-incremented), gossip it to
                            the current view, deliver immediately — the
                            O(N) piggyback Table 1 charges per message
  ``on_receive``            gossip-forward on first receipt (dedup on
                            message id), then park in ``pending`` (W)
  ``_ready``                the delivery condition: every clock entry
                            satisfied, sender entry off by exactly one —
                            one O(N) scan per check
  ``_drain``                re-scan pending after every delivery until a
                            fixpoint: the O(W·N) delivery execution time
  ``local_space_entries``   Table 1's local-space metric: clock entries
                            plus the clocks of parked messages

The vectorized twin of this protocol (``repro.core.vecsim.vc``) runs
the same semantics as dense arrays at large N, so ``bench_table1
--engine vec`` reports *measured* VC columns; its delivered multisets
and final clock values are cross-validated byte-identical against this
class on the exact engine (``cross_validate(..., protocol="vc")``).
The older analytic approximation (``vecsim.vc_overhead_model``) is kept
for contrast as the benchmark's ``vc_model`` rows.
"""

from __future__ import annotations

from typing import Any, Dict, List, Set, Tuple

from .base import AppMsg, Protocol, msg_id

__all__ = ["VCBroadcast"]


class VCBroadcast(Protocol):
    def __init__(self, pid: int, deliver_cb=None):
        super().__init__(pid, deliver_cb)
        self.Q: Set[int] = set()
        self.vc: Dict[int, int] = {}                 # pid -> delivered count
        self.pending: List[AppMsg] = []              # W: awaiting delivery
        self.received: Set[Tuple[int, int]] = set()  # gossip dedup
        self.comparisons = 0                         # delivery-time metric
        self.max_pending = 0

    # -- membership: every link is usable immediately -------------------- #
    def on_open(self, q: int) -> None:
        self.Q.add(q)

    def on_close(self, q: int) -> None:
        self.Q.discard(q)

    # -- dissemination ----------------------------------------------------- #
    def broadcast(self, payload: Any = None) -> AppMsg:
        self.counter += 1
        ts = dict(self.vc)
        ts[self.pid] = ts.get(self.pid, 0) + 1
        m = AppMsg(self.pid, self.counter, payload, vc=tuple(sorted(ts.items())))
        self.net.record_broadcast(self.pid, m)
        self.received.add(msg_id(m))
        for q in list(self.Q):
            self.send(q, m)
        self.vc[self.pid] = ts[self.pid]
        self.deliver(m)
        return m

    def on_receive(self, src: int, msg: Any) -> None:
        if not isinstance(msg, AppMsg):
            return
        if msg_id(msg) in self.received:
            self.net.stats.duplicate_receipts += 1
            return
        self.received.add(msg_id(msg))
        for q in list(self.Q):                       # gossip forward
            self.send(q, msg)
        self.pending.append(msg)
        self.max_pending = max(self.max_pending, len(self.pending))
        self._drain()

    # -- causal delivery --------------------------------------------------- #
    def _ready(self, m: AppMsg) -> bool:
        ts = dict(m.vc)
        for k, v in ts.items():
            self.comparisons += 1
            have = self.vc.get(k, 0)
            need = v - 1 if k == m.origin else v
            if have < need:
                return False
        return True

    def _drain(self) -> None:
        """Re-scan pending after each delivery: the O(W·N) loop."""
        progress = True
        while progress:
            progress = False
            for m in list(self.pending):
                if self._ready(m):
                    self.pending.remove(m)
                    self.vc[m.origin] = self.vc.get(m.origin, 0) + 1
                    self.deliver(m)
                    progress = True

    # -- metrics ----------------------------------------------------------- #
    def local_space_entries(self) -> int:
        """Vector entries + pending-message vector entries (Table 1 space)."""
        return len(self.vc) + sum(len(m.vc) for m in self.pending)
