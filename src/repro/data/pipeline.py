"""Deterministic synthetic token pipeline.

Generates language-like token streams from a seeded Markov-ish process
entirely on the host, with: deterministic resume (state = (seed, step)),
per-data-shard slicing (each data-parallel rank reads only its rows), and
double-buffered prefetch.  Loss on this data genuinely decreases under
training (local bigram structure), which the gossip-convergence tests and
examples rely on.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "prefetch"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0          # this host's data shard
    num_shards: int = 1
    n_modes: int = 32       # latent bigram modes (structure to learn)


class SyntheticLM:
    """Stateless-resumable synthetic LM batches.

    Each sequence follows one of ``n_modes`` latent cyclic bigram chains
    plus noise — enough structure that even small models show steadily
    decreasing loss, while batch generation stays O(B*S) numpy."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.num_shards == 0
        self.local_batch = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # mode m walks tokens in arithmetic progression step_m (mod v)
        self.mode_step = rng.integers(1, v - 1, size=cfg.n_modes)
        self.mode_start = rng.integers(0, v, size=cfg.n_modes)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for global ``step`` — pure function of (seed, step, shard)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + cfg.shard)
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        modes = rng.integers(0, cfg.n_modes, size=(b, 1))
        start = self.mode_start[modes] + rng.integers(0, v, size=(b, 1))
        ar = start + self.mode_step[modes] * np.arange(s + 1)[None, :]
        toks = ar % v
        noise = rng.random((b, s + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, v, size=(b, s + 1)), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch (double buffering)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for x in it:
                q.put(x)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        x = q.get()
        if x is stop:
            return
        yield x
