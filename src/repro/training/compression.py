"""Gradient/update compression for the cross-pod gossip plane.

Top-k sparsification with error feedback (memory): the residual of what
was not transmitted is carried into the next round, so the compressed
gossip remains unbiased over time.  Payloads shrink by ~(1 - k/n) x 2
(values + int32 indices vs dense f32), which is what keeps outer-update
dissemination cheap at fleet scale.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["topk_compress", "topk_decompress", "ErrorFeedback",
           "payload_bytes"]


def topk_compress(tree, frac: float):
    """Keep the largest-|value| ``frac`` of entries per leaf.

    Returns a compressed pytree of (indices, values, shape) per leaf."""
    def one(x):
        x = jnp.asarray(x)
        n = x.size
        k = max(1, int(n * frac))
        flat = x.reshape(-1)
        idx = jnp.argsort(jnp.abs(flat))[-k:]
        return (idx.astype(jnp.int32), flat[idx], x.shape)
    return jax.tree.map(one, tree)


def topk_decompress(ctree):
    def one(t):
        idx, vals, shape = t
        n = int(np.prod(shape))
        return jnp.zeros((n,), vals.dtype).at[idx].set(vals).reshape(shape)
    return jax.tree.map(one, ctree,
                        is_leaf=lambda t: isinstance(t, tuple)
                        and len(t) == 3)


def payload_bytes(ctree) -> int:
    total = 0
    for idx, vals, _ in jax.tree.leaves(
            ctree, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3):
        total += idx.size * 4 + vals.size * vals.dtype.itemsize
    return total


class ErrorFeedback:
    """Residual memory: compress(update + residual); residual carries the
    untransmitted remainder."""

    def __init__(self, frac: float):
        self.frac = frac
        self.residual = None

    def compress(self, tree):
        if self.residual is not None:
            tree = jax.tree.map(jnp.add, tree, self.residual)
        ctree = topk_compress(tree, self.frac)
        sent = topk_decompress(ctree)
        self.residual = jax.tree.map(jnp.subtract, tree, sent)
        return ctree
