"""Train/serve step builders: loss, gradient accumulation, optimizer.

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with in/out shardings from
``repro.sharding.policy``.  Microbatching (gradient accumulation) runs as
a ``lax.scan`` over leading splits of the batch so the HLO stays compact.

``make_prefill_step`` / ``make_decode_step`` wrap the model's serving
entry points with the same signature discipline.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, OptState, adamw_update

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean CE; logits f32 (B, S, V), labels (B, S) int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def make_loss_fn(model, aux_coef: float = 1e-2):
    def loss_fn(params, batch):
        logits, aux, _, _ = model.forward(
            params,
            tokens=batch.get("tokens"),
            positions=batch.get("positions"),
            embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"))
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        return ce + aux_coef * aux, {"ce": ce, "aux": aux}
    return loss_fn


def _split_batch(batch: Dict[str, Any], n: int):
    """(B, ...) -> (n, B//n, ...) for every array in the batch dict."""
    return {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            for k, v in batch.items() if v is not None}


def make_train_step(model, opt_cfg: AdamWConfig, microbatches: int = 1,
                    aux_coef: float = 1e-2,
                    lr_schedule: Optional[Callable] = None,
                    unroll: bool = False,
                    param_axes=None, compute_policy: Optional[str] = None):
    """``unroll`` replaces the microbatch scan with a python loop so the
    dry-run's cost variants price every microbatch (DESIGN.md §6).

    ``param_axes`` + ``compute_policy='tp'``: re-shard FSDP params to the
    TP layout once at step entry, so the forward/backward's parameter
    all-gather happens once per step instead of once per microbatch."""
    loss_fn = make_loss_fn(model, aux_coef)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        if param_axes is not None and compute_policy is not None:
            from repro.sharding.policy import reshard_tree
            params = reshard_tree(params, param_axes, compute_policy)
        if microbatches <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            mb = _split_batch(batch, microbatches)

            def body(acc, one):
                (l, p), g = grad_fn(params, one)
                acc = jax.tree.map(jnp.add, acc,
                                   (g, {"loss": l, "ce": p["ce"],
                                        "aux": p["aux"]}))
                return acc, None

            zero_g = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            zero_m = {"loss": jnp.zeros(()), "ce": jnp.zeros(()),
                      "aux": jnp.zeros(())}
            acc = (zero_g, zero_m)
            if unroll:
                for i in range(microbatches):
                    one = jax.tree.map(lambda t: t[i], mb)
                    acc, _ = body(acc, one)
                gsum, msum = acc
            else:
                (gsum, msum), _ = jax.lax.scan(body, acc, mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, gsum)
            loss = msum["loss"] * inv
            parts = {"ce": msum["ce"] * inv, "aux": msum["aux"] * inv}

        lr_scale = (lr_schedule(opt_state.step) if lr_schedule is not None
                    else 1.0)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state, lr_scale)
        metrics = {"loss": loss, **parts, **om,
                   "step": opt_state.step.astype(jnp.float32)}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch, pad_to: Optional[int] = None):
        return model.prefill(params,
                             tokens=batch.get("tokens"),
                             positions=batch.get("positions"),
                             embeds=batch.get("embeds"),
                             enc_embeds=batch.get("enc_embeds"),
                             pad_to=pad_to)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, token, caches, cur_index):
        logits, caches = model.decode_step(params, token, caches, cur_index)
        return logits, caches
    return decode_step
