"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_linear", "constant"]


def constant(value: float = 1.0) -> Callable:
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    """Linear warmup 0->1 then cosine decay 1->final_frac (as a multiplier
    on AdamWConfig.lr)."""

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        t = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn


def warmup_linear(warmup_steps: int, total_steps: int,
                  final_frac: float = 0.0) -> Callable:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        t = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        lin = 1.0 - (1.0 - final_frac) * jnp.clip(t, 0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, lin)

    return fn
