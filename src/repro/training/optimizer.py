"""AdamW with global-norm clipping — self-contained (no optax).

Optimizer moments are plain pytrees mirroring the params, so ZeRO-1 is
purely a sharding statement: ``repro.sharding.policy`` gives m/v the fsdp
rules (sharded over the data axes) even when params are tensor-parallel
replicated, and XLA inserts the reduce-scatter/all-gather pair around the
update.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Mixed precision: bf16 live params + f32 master copy in the (ZeRO-
    # sharded) optimizer state.  Halves gradient all-reduce wire and
    # parameter HBM traffic; the update math stays f32.
    master_weights: bool = False


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray
    master: Any = None     # f32 params (master_weights mode) or None


def init_opt_state(params, master_weights: bool = False) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if master_weights else None)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32),
                    master=master)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, w):
        """w = f32 master (or p itself when not in master mode)."""
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        wf = w.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * wf
        new_w = wf - lr * delta
        return new_w.astype(p.dtype), m, v, new_w

    masters = state.master if state.master is not None else params
    out = jax.tree.map(upd, params, grads, state.m, state.v, masters)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_params, new_m, new_v = pick(0), pick(1), pick(2)
    new_master = pick(3) if state.master is not None else None
    return new_params, OptState(new_m, new_v, step, new_master), {
        "grad_norm": gnorm, "clip_scale": scale}
