"""Scalability beyond the paper: Python event core vs tensorized JAX
engine (per-round cell-update throughput), N up to 10k on one CPU core.

CSV:  engine/<impl>/N=<n>,us_per_call(run),derived(M cell-rounds/s)
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BoundedPCBroadcast, Network, ring_plus_random
from repro.core.engine import analyze, random_instance, run_engine


def python_core(n: int, n_bcast: int = 16):
    net = Network(seed=1, default_delay=1.0, oob_delay=0.5)
    for pid in range(n):
        net.add_process(BoundedPCBroadcast(pid, ping_mode="route"))
    ring_plus_random(net, range(n), k=8)
    t0 = time.perf_counter()
    for i in range(n_bcast):
        net.procs[(i * 7) % n].broadcast(("m", i))
        net.run(until=net.time + 1.0)
    net.run()
    dt = time.perf_counter() - t0
    # normalize to the same unit as the engine: process x msg x round
    rounds = max(1, int(net.time))
    cell_rounds = n * n_bcast * rounds
    return dt, cell_rounds / dt / 1e6


def jax_engine(n: int, m: int = 64, rounds: int = 64):
    cfg, sched, adj0, delay0 = random_instance(
        5, n=n, k=8, m_app=m, n_adds=24, n_rms=24, rounds=rounds,
        mode="pc")
    run_engine(cfg, sched, adj0, delay0)          # compile
    t0 = time.perf_counter()
    d = run_engine(cfg, sched, adj0, delay0)
    dt = time.perf_counter() - t0
    rep = analyze(d, sched)
    assert rep["violations"] == 0
    cell_rounds = n * sched.m_total * rounds
    return dt, cell_rounds / dt / 1e6


def rows():
    out = []
    for n in (500, 2000):
        dt, thr = python_core(n)
        out.append((f"engine/python/N={n}", dt * 1e6, thr))
    for n in (2000, 10_000):
        dt, thr = jax_engine(n)
        out.append((f"engine/jax/N={n}", dt * 1e6, thr))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.0f},{derived:.2f}")


if __name__ == "__main__":
    main()
