"""Million-process scale benchmark for the device-sharded engine.

Drives a sustained-traffic run at N ≥ 1M processes through
``repro.api.run`` with ``engine="sharded"`` — the population regime the
paper's constant-size control information exists for, and two orders of
magnitude past the single-host engines (the monolithic engine caps near
N ≈ 100k; the windowed engine holds the traffic axis but still keeps
every (N, W) plane on one device).  The process axis is partitioned
over a ``shard_map`` device mesh; on CPU the mesh comes from forced
host platform devices, which this script sets up itself (the flag must
precede jax initialization)::

    PYTHONPATH=src python benchmarks/bench_scale.py \
        --n 1048576 --devices 4 --messages 512 --rate 4 --window 128

Measurement: the run always profiles per segment (``shard.profile``).
The first segment of each distinct segment program (the bit-packed fast
body and the generic scanned body compile separately) is the *warmup*
segment — its wall time includes jit tracing and XLA compilation — so
the headline throughput (``sends_per_sec_steady``) is recomputed from
the steady-state segments only, with the compile cost reported
separately as ``compile_s``.  The naive whole-run rate stays in the
JSON as ``sends_per_sec`` for comparability with older snapshots.

Reports simulated broadcasts/s and message-copies (sends)/s of wall
clock, delivered fraction, mean delivery latency, the live-column
high-water mark, and the per-device buffer bytes the window pinned.
Writes everything to ``BENCH_scale.json`` (``--out``), optionally a
per-segment host/device timing artifact (``--segments-out``), and
prints the usual ``name,us_per_call,derived`` CSV rows.  CI regression
floor: ``--assert-floor 0.3 --floor-ref BENCH_scale.json`` fails the
run when steady throughput drops more than 30% below the committed
reference on the same host class.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def _steady_state(seg_profile, series):
    """Split the profiled segments into warmup and steady state.

    Returns ``(compile_s, steady_s, steady_sends, segments)`` where
    ``segments`` is the JSON-ready per-segment breakdown: round bounds,
    which body ran, the four wall components, and the send count the
    segment's rounds produced (from the per-round series, so the split
    never changes the totals)."""
    segments = []
    for p in seg_profile:
        sent = int(series[p["lo"]:p["hi"], 1:4].sum())
        wall = (p["stage_s"] + p["dispatch_s"] + p["block_s"]
                + p["retire_s"])
        segments.append(dict(p, sends=sent, wall_s=wall))
    warm_idx = {}
    for i, s in enumerate(segments):
        warm_idx.setdefault(s["fast"], i)
    warm = set(warm_idx.values())
    steady = [s for i, s in enumerate(segments) if i not in warm]
    steady_s = sum(s["wall_s"] for s in steady)
    steady_sends = sum(s["sends"] for s in steady)
    # compile estimate: how much longer each kind's first segment took
    # than that kind's median steady segment
    compile_s = 0.0
    for kind, i in warm_idx.items():
        peers = sorted(s["wall_s"] for s in steady if s["fast"] == kind)
        if peers:
            compile_s += max(0.0, segments[i]["wall_s"]
                             - peers[len(peers) // 2])
        else:
            compile_s += segments[i]["wall_s"]
    for i, s in enumerate(segments):
        s["warmup"] = i in warm
    return compile_s, steady_s, steady_sends, segments


def run_point(n: int, devices: int, messages: int, rate: float,
              window: int, k: int, topology: str, traffic: str,
              seg_len: int, horizon: int | None, max_delay: int,
              seed: int, scan: str = "auto", obs=None) -> dict:
    from dataclasses import replace

    from repro.api import (ObsSpec, RunSpec, ShardSpec, TopologySpec,
                           TrafficSpec, WindowSpec, build_scenario, run)
    from repro.core.vecsim.shard import pad_rows

    spec = RunSpec(
        protocol="pc", engine="sharded", n=n, seed=seed,
        shard=ShardSpec(devices=devices, scan=scan, profile=True),
        topology=TopologySpec(kind=topology, k=k, max_delay=max_delay),
        traffic=TrafficSpec(kind=traffic, rate=rate, messages=messages),
        window=WindowSpec(window=window, seg_len=seg_len, horizon=horizon,
                          collect="aggregate"),
        # throughput microbench: telemetry off by default so the
        # committed floor keeps measuring the bare engine (the
        # obs-overhead bench measures both sides explicitly)
        obs=obs if obs is not None else ObsSpec(histograms=False))
    t0 = time.perf_counter()
    scn = build_scenario(spec.validate())
    build_s = time.perf_counter() - t0
    # hand the prebuilt scenario back so the report's wall clock is pure
    # engine time, with the build cost reported separately
    rep = run(replace(spec, scenario=scn))
    res, run_s = rep.result, rep.wall_seconds
    if horizon is None:
        # without a horizon the engine is exact: anything less than full
        # delivery is a correctness regression, not a number
        assert not res.expired.any(), "columns expired without a horizon"
        assert rep.delivered_frac == 1.0, \
            f"sharded run did not quiesce ({rep.delivered_frac:.6f})"
    compile_s, steady_s, steady_sends, segments = _steady_state(
        res.seg_profile, res.series)
    n_pad = pad_rows(n, res.n_devices)
    buffer_bytes = 2 * n_pad * window * 4          # arr + delivered, int32
    point = dict(
        n=n, devices=res.n_devices, k=k, messages=messages, rate=rate,
        window=window, topology=topology, traffic=traffic,
        seg_len=seg_len, horizon=horizon, scan=rep.extras["scan"],
        rounds=scn.rounds,
        build_seconds=round(build_s, 3),
        run_seconds=round(run_s, 3),
        compile_s=round(compile_s, 3),
        steady_run_seconds=round(steady_s, 3),
        msgs_per_sec=round(messages / run_s, 1),
        sends=res.stats.sent_messages,
        sends_per_sec=round(res.stats.sent_messages / run_s, 1),
        steady_sends=steady_sends,
        sends_per_sec_steady=round(steady_sends / steady_s, 1)
        if steady_s > 0 else None,
        deliveries=res.stats.deliveries,
        delivered_frac=round(rep.delivered_frac, 6),
        mean_latency_rounds=round(rep.mean_latency, 3),
        peak_live=res.peak_live,
        expired=int(res.expired.sum()),
        window_buffer_bytes=buffer_bytes,
        buffer_bytes_per_device=buffer_bytes // res.n_devices,
    )
    return point, segments


def steady_rate(point: dict) -> float:
    """The comparable throughput of a bench point: steady-state when
    recorded, the whole-run rate for pre-S2 snapshots."""
    rate = point.get("sends_per_sec_steady")
    return float(rate if rate else point["sends_per_sec"])


def rows(n: int = 1 << 20, devices: int = 4, messages: int = 512,
         rate: float = 4.0, window: int = 128, k: int = 4,
         topology: str = "kregular", traffic: str = "poisson",
         seg_len: int = 32, horizon: int | None = None,
         max_delay: int = 1, seed: int = 0, out: str | None = None,
         scan: str = "auto", segments_out: str | None = None):
    point, segments = run_point(n, devices, messages, rate, window, k,
                                topology, traffic, seg_len, horizon,
                                max_delay, seed, scan)
    if out:
        from repro.obs.report import write_bench_report
        write_bench_report(out, "scale", point)
    if segments_out:
        with open(segments_out, "w") as fh:
            json.dump(dict(n=n, devices=point["devices"],
                           seg_len=seg_len, scan=point["scan"],
                           segments=segments), fh, indent=2)
    us = point["run_seconds"] * 1e6
    tag = f"n={n},d={point['devices']}"
    return point, [
        (f"scale/msgs_per_sec/{tag}", us, point["msgs_per_sec"]),
        (f"scale/sends_per_sec/{tag}", us, point["sends_per_sec"]),
        (f"scale/sends_per_sec_steady/{tag}", us, steady_rate(point)),
        (f"scale/compile_s/{tag}", us, point["compile_s"]),
        (f"scale/delivered_frac/{tag}", us, point["delivered_frac"]),
        (f"scale/latency_rounds/{tag}", us, point["mean_latency_rounds"]),
        (f"scale/peak_live/{tag}", us, float(point["peak_live"])),
        (f"scale/buffer_mb_per_device/{tag}", us,
         point["buffer_bytes_per_device"] / 2 ** 20),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 20,
                    help="processes (default 1,048,576)")
    ap.add_argument("--devices", type=int, default=4,
                    help="device-mesh size the process axis shards over")
    ap.add_argument("--no-force-host", action="store_true",
                    help="do not force host platform devices (use this "
                         "on a real accelerator mesh)")
    ap.add_argument("--messages", type=int, default=512)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean broadcasts per lockstep round")
    ap.add_argument("--window", type=int, default=128,
                    help="live message columns "
                         "(memory = 8·N·window bytes across the mesh)")
    ap.add_argument("--k", type=int, default=4, help="out-links per process")
    ap.add_argument("--topology", choices=("kregular", "ring", "smallworld"),
                    default="kregular")
    ap.add_argument("--traffic", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--seg-len", type=int, default=32,
                    help="rounds per jitted segment between retirements")
    ap.add_argument("--horizon", type=int, default=None,
                    help="force-retire columns older than this many rounds")
    ap.add_argument("--max-delay", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scan", choices=("auto", "on", "off"), default="auto",
                    help="segment stepping: one lax.scan per segment (on, "
                         "the auto default) vs per-round dispatch (off)")
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--segments-out", default=None,
                    help="also write the per-segment host/device timing "
                         "breakdown (CI artifact)")
    ap.add_argument("--assert-floor", type=float, default=None,
                    metavar="FRAC",
                    help="fail if steady sends/s drops more than FRAC "
                         "below the --floor-ref snapshot (e.g. 0.3)")
    ap.add_argument("--floor-ref", default="BENCH_scale.json",
                    help="committed reference snapshot for --assert-floor")
    args = ap.parse_args()
    # the forced-host-device flag must land before jax initializes, so
    # it happens here, ahead of any repro.api import
    if not args.no_force_host and args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    ref = None
    if args.assert_floor is not None:
        # read the reference before --out can overwrite the same file
        from repro.obs.report import load_bench_report
        ref = load_bench_report(args.floor_ref, kind="scale")
    point, csv = rows(args.n, args.devices, args.messages, args.rate,
                      args.window, args.k, args.topology, args.traffic,
                      args.seg_len, args.horizon, args.max_delay,
                      args.seed, args.out, args.scan, args.segments_out)
    for name, us, derived in csv:
        print(f"{name},{us:.0f},{derived:.3f}")
    if ref is not None:
        # sends/s is work-per-wall-second, so it compares across N; the
        # slack fraction absorbs host noise and working-set effects
        floor = (1.0 - args.assert_floor) * steady_rate(ref)
        got = steady_rate(point)
        if got < floor:
            print(f"FLOOR VIOLATION: steady sends/s {got:.0f} < "
                  f"{floor:.0f} ({(1 - args.assert_floor) * 100:.0f}% of "
                  f"reference {steady_rate(ref):.0f})", file=sys.stderr)
            sys.exit(1)
        print(f"floor ok: steady sends/s {got:.0f} >= {floor:.0f}")


if __name__ == "__main__":
    main()
