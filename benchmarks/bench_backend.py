"""jax-vs-pallas backend comparison on the vecsim hot path.

Runs the identical windowed sustained ``RunSpec`` once per backend
(jax, then pallas) through ``repro.api.run``, checks the two runs agree
on the protocol numbers (sends, deliveries, delivered fraction — the
byte-identity the test suite asserts in full), and records rounds/sec
and messages/sec side by side in ``BENCH_backend.json``.

What the numbers mean depends on where Pallas runs (the JSON records
it): on a TPU the kernels compile and the comparison measures the fused
delivery sweep against the plain ``lax.scan`` body; everywhere else
Pallas executes in interpret mode — byte-identical but paying the
interpreter's lowering overhead — so the comparison documents the cost
of the testing path, not a speedup.  ``pallas_mode`` in the JSON is the
availability probe's note.

    PYTHONPATH=src python benchmarks/bench_backend.py \
        --n 2048 --messages 4096 --rate 64 --window 512 \
        --out BENCH_backend.json

``--smoke`` shrinks the point for CI (the kernel-smoke job runs it on
every push).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

BACKENDS = ("jax", "pallas")


def run_point(backend: str, scn, spec) -> dict:
    from dataclasses import replace

    from repro.api import run

    rep = run(replace(spec, backend=backend, scenario=scn))
    res, wall = rep.result, rep.wall_seconds
    return dict(
        backend=rep.backend, rounds=scn.rounds,
        run_seconds=round(wall, 3),
        rounds_per_sec=round(scn.rounds / wall, 1),
        msgs_per_sec=round(scn.m_app / wall, 1),
        sends=res.stats.sent_messages,
        deliveries=res.stats.deliveries,
        delivered_frac=round(rep.delivered_frac, 6),
        peak_live=res.peak_live,
    )


def rows(n: int = 2048, messages: int = 4096, rate: float = 64.0,
         window: int = 512, k: int = 6, seg_len: int = 8,
         max_delay: int = 1, seed: int = 0, out: str | None = None):
    from repro.api import (BACKENDS as BACKEND_REGISTRY, RunSpec,
                           TopologySpec, TrafficSpec, WindowSpec,
                           build_scenario)

    spec = RunSpec(
        protocol="pc", engine="windowed", n=n, seed=seed,
        topology=TopologySpec(kind="kregular", k=k, max_delay=max_delay),
        traffic=TrafficSpec(kind="poisson", rate=rate, messages=messages),
        window=WindowSpec(window=window, seg_len=seg_len,
                          collect="aggregate"))
    t0 = time.perf_counter()
    scn = build_scenario(spec.validate())
    build_s = time.perf_counter() - t0
    points = [run_point(backend, scn, spec) for backend in BACKENDS]
    jaxp, palp = points
    # the backends must tell the same protocol story before their wall
    # clocks are worth comparing
    for key in ("sends", "deliveries", "delivered_frac", "peak_live"):
        assert jaxp[key] == palp[key], (key, jaxp[key], palp[key])
    ok, note = BACKEND_REGISTRY.get("pallas").probe()
    doc = dict(
        n=n, k=k, messages=messages, rate=rate, window=window,
        seg_len=seg_len, rounds=scn.rounds,
        build_seconds=round(build_s, 3),
        pallas_available=ok, pallas_mode=note,
        points=points,
        pallas_vs_jax_speedup=round(
            jaxp["run_seconds"] / palp["run_seconds"], 3),
    )
    if out:
        from repro.obs.report import write_bench_report
        write_bench_report(out, "backend", doc)
    tag = f"n={n},m={messages},w={window}"
    out_rows = []
    for point in points:
        us = point["run_seconds"] * 1e6
        out_rows += [
            (f"backend/{point['backend']}/rounds_per_sec/{tag}", us,
             point["rounds_per_sec"]),
            (f"backend/{point['backend']}/msgs_per_sec/{tag}", us,
             point["msgs_per_sec"]),
        ]
    out_rows.append((f"backend/pallas_vs_jax_speedup/{tag}",
                     palp["run_seconds"] * 1e6,
                     doc["pallas_vs_jax_speedup"]))
    return out_rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--messages", type=int, default=4096)
    ap.add_argument("--rate", type=float, default=64.0,
                    help="mean broadcasts per lockstep round")
    ap.add_argument("--window", type=int, default=512,
                    help="live message columns (memory = 8·N·window bytes)")
    ap.add_argument("--k", type=int, default=6, help="out-links per process")
    ap.add_argument("--seg-len", type=int, default=8,
                    help="rounds per jitted segment between retirements")
    ap.add_argument("--max-delay", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized point (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_backend.json")
    args = ap.parse_args()
    if args.smoke:
        args.n, args.messages, args.rate = 256, 512, 16.0
        args.window = 128
    for name, us, derived in rows(args.n, args.messages, args.rate,
                                  args.window, args.k, args.seg_len,
                                  args.max_delay, args.seed, args.out):
        print(f"{name},{us:.0f},{derived:.3f}")


if __name__ == "__main__":
    main()
