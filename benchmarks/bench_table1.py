"""Table 1 reproduction: message overhead, delivery execution time, and
local space for vector-clock causal broadcast vs. PC-broadcast — both
protocols **measured**, on either engine, through the one front door
(``repro.api.run``).

Two engines (``--engine``):

  * ``exact`` — both protocols run as Python processes on the event
    simulator at N in {50, 100, 200}, oracle-checked;
  * ``vec``   — both protocols run on the vectorized lockstep substrate
    at N in {1000, 10000, 50000}: PC-broadcast on the shared vec engine,
    the vector-clock baseline on its dense-clock vec twin
    (``vecsim.vc``), on the *same scenario* (same seed, topology and
    broadcast schedule), so the O(1)-vs-O(N) separation is measured —
    per-hop piggyback bytes and readiness-scan comparison counts — at
    population sizes the object simulator cannot reach.  The analytic
    model the measured columns replace is kept as ``vc_model`` rows for
    contrast.

Emits CSV rows  name,us_per_call,derived  where ``derived`` is the
table's complexity metric (bytes/message, comparisons/delivery, entries).
"""

from __future__ import annotations

import argparse

from repro.api import (MetricsSpec, RunSpec, TopologySpec, TrafficSpec,
                       WindowSpec, run)
from repro.core.vecsim import vc_overhead_model


def _spec(protocol: str, engine: str, n: int, m_app: int, k: int,
          backend: str = "numpy", window: int | None = None,
          oracle: bool = False) -> RunSpec:
    """One Table 1 cell: a static overlay with ``m_app`` broadcasts.
    The scenario depends only on (seed, n, k, m_app), so the pc and vc
    runs of a size execute the identical causal workload."""
    return RunSpec(
        protocol=protocol, engine=engine, backend=backend, n=n, seed=n,
        topology=TopologySpec(kind="ring", k=k),
        traffic=TrafficSpec(kind="uniform", messages=m_app),
        window=WindowSpec(window=window),
        metrics=MetricsSpec(oracle=oracle))


def rows_exact(sizes=(50, 100, 200)):
    out = []
    for n in sizes:
        # broadcasters scale with N so the vector-clock entry count (one
        # per process that EVER broadcast — the paper's N) grows too
        m_app = n // 2
        k = max(3, n // 32)
        # --- PC-broadcast -------------------------------------------- #
        rep = run(_spec("pc", "exact", n, m_app, k, oracle=True))
        assert rep.oracle.ok, rep.oracle.summary()
        us = rep.wall_seconds / max(rep.stats.deliveries, 1) * 1e6
        out.append((f"table1/pc/overhead_bytes/N={n}", us,
                    rep.extras["overhead_bytes_per_msg"]))
        space = max(len(p.received) for p in rep.result.procs.values())
        out.append((f"table1/pc/space_entries/N={n}", us, space))

        # --- vector clocks -------------------------------------------- #
        rep = run(_spec("vc", "exact", n, m_app, k, oracle=True))
        assert rep.oracle.ok, rep.oracle.summary()
        us = rep.wall_seconds / max(rep.stats.deliveries, 1) * 1e6
        out.append((f"table1/vc/overhead_bytes/N={n}", us,
                    rep.extras["overhead_bytes_per_msg"]))
        out.append((f"table1/vc/comparisons_per_delivery/N={n}", us,
                    rep.extras["comparisons_per_delivery"]))
        out.append((f"table1/vc/space_entries/N={n}", us,
                    rep.extras["space_entries_max"]))
    return out


def rows_vec(sizes=(1000, 10_000, 50_000), backend: str = "numpy",
             window: int | None = None):
    out = []
    for n in sizes:
        m_app = 32
        # --- PC-broadcast on the shared vec engine --------------------- #
        rep = run(_spec("pc", "windowed" if window else "vec", n, m_app,
                        k=6, backend=backend, window=window))
        assert rep.delivered_frac == 1.0
        us = rep.wall_seconds / max(rep.stats.deliveries, 1) * 1e6
        out.append((f"table1/pc/overhead_bytes/N={n}", us,
                    rep.extras["overhead_bytes_per_msg"]))
        # received-set entries: every process ends up knowing every id
        out.append((f"table1/pc/space_entries/N={n}", us, m_app))
        # the replaced analytic model, kept for contrast with measurement
        if rep.result.delivered is not None:
            mb, mc = vc_overhead_model(rep.result)
            out.append((f"table1/vc_model/overhead_bytes/N={n}", us, mb))
            out.append((f"table1/vc_model/comparisons_per_delivery/N={n}",
                        us, mc))

        # --- vector clocks, measured on the same scenario -------------- #
        rep = run(_spec("vc", "vec", n, m_app, k=6))
        assert rep.delivered_frac == 1.0
        us = rep.wall_seconds / max(rep.stats.deliveries, 1) * 1e6
        out.append((f"table1/vc/overhead_bytes/N={n}", us,
                    rep.extras["overhead_bytes_per_msg"]))
        out.append((f"table1/vc/comparisons_per_delivery/N={n}", us,
                    rep.extras["comparisons_per_delivery"]))
        out.append((f"table1/vc/space_entries/N={n}", us,
                    rep.extras["space_entries_max"]))
    return out


def rows(engine: str = "exact", n: int | None = None,
         backend: str = "numpy", window: int | None = None):
    if engine == "vec":
        return rows_vec((n,) if n is not None else (1000, 10_000, 50_000),
                        backend=backend, window=window)
    return rows_exact((n,) if n is not None else (50, 100, 200))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("exact", "vec"), default="exact")
    ap.add_argument("--n", type=int, default=None,
                    help="single population size (default: engine sweep)")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas", "auto"),
                    default="numpy")
    ap.add_argument("--window", type=int, default=None,
                    help="route the pc vec runs through the streaming "
                         "windowed engine with this many live columns")
    args = ap.parse_args()
    for name, us, derived in rows(args.engine, args.n, args.backend,
                                  args.window):
        print(f"{name},{us:.2f},{derived:.2f}")


if __name__ == "__main__":
    main()
