"""Table 1 reproduction: message overhead, delivery execution time, and
local space for vector-clock causal broadcast vs. PC-broadcast.

Emits CSV rows  name,us_per_call,derived  where ``derived`` is the
table's complexity metric (bytes/message, comparisons/delivery, entries).
"""

from __future__ import annotations

import time

from repro.core import (BoundedPCBroadcast, Network, VCBroadcast,
                        check_trace, ring_plus_random)
from repro.core.metrics import overhead_per_message


def run_broadcasts(proto_cls, n, n_bcast, seed=0, **kw):
    net = Network(seed=seed, default_delay=0.5, oob_delay=0.25)
    for pid in range(n):
        net.add_process(proto_cls(pid, **kw))
    ring_plus_random(net, range(n), k=max(3, n // 32))
    t0 = time.perf_counter()
    for i in range(n_bcast):
        net.procs[i % n].broadcast(("m", i))
        net.run(until=net.time + 0.7)
    net.run()
    wall = time.perf_counter() - t0
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()
    return net, wall, rep


def rows():
    out = []
    for n in (50, 100, 200):
        # broadcasters scale with N so the vector-clock entry count (one
        # per process that EVER broadcast — the paper's N) grows too
        n_bcast = n // 2
        # --- PC-broadcast -------------------------------------------- #
        net, wall, rep = run_broadcasts(
            lambda pid: BoundedPCBroadcast(pid, ping_mode="route"), n,
            n_bcast)
        per_delivery_us = wall / max(rep.n_deliveries, 1) * 1e6
        out.append((f"table1/pc/overhead_bytes/N={n}", per_delivery_us,
                    overhead_per_message(net)))
        space = max(len(p.received) for p in net.procs.values())
        out.append((f"table1/pc/space_entries/N={n}", per_delivery_us,
                    space))

        # --- vector clocks -------------------------------------------- #
        net, wall, rep = run_broadcasts(VCBroadcast, n, n_bcast)
        per_delivery_us = wall / max(rep.n_deliveries, 1) * 1e6
        out.append((f"table1/vc/overhead_bytes/N={n}", per_delivery_us,
                    overhead_per_message(net)))
        comparisons = sum(p.comparisons for p in net.procs.values())
        out.append((f"table1/vc/comparisons_per_delivery/N={n}",
                    per_delivery_us,
                    comparisons / max(rep.n_deliveries, 1)))
        space = max(p.local_space_entries() for p in net.procs.values())
        out.append((f"table1/vc/space_entries/N={n}", per_delivery_us,
                    space))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.2f},{derived:.2f}")


if __name__ == "__main__":
    main()
