"""Table 1 reproduction: message overhead, delivery execution time, and
local space for vector-clock causal broadcast vs. PC-broadcast.

Two engines (``--engine``):

  * ``exact`` — both protocols actually run as Python processes on the
    event simulator at N in {50, 100, 200}, oracle-checked;
  * ``vec``   — PC-broadcast runs on the vectorized lockstep engine at
    N in {1000, 10000, 50000}; the vector-clock column is *derived* from
    the same causal run (``vecsim.vc_overhead_model``: one clock entry
    per origin the broadcaster had delivered from, one rescan of the
    clock per delivery), which is what extends Table 1's O(1)-vs-O(N)
    separation to population sizes the object simulator cannot reach.

Emits CSV rows  name,us_per_call,derived  where ``derived`` is the
table's complexity metric (bytes/message, comparisons/delivery, entries).
"""

from __future__ import annotations

import argparse
import time

from repro.core import (BoundedPCBroadcast, Network, VCBroadcast,
                        check_trace, ring_plus_random)
from repro.core.metrics import overhead_per_message


def run_broadcasts(proto_cls, n, n_bcast, seed=0, **kw):
    net = Network(seed=seed, default_delay=0.5, oob_delay=0.25)
    for pid in range(n):
        net.add_process(proto_cls(pid, **kw))
    ring_plus_random(net, range(n), k=max(3, n // 32))
    t0 = time.perf_counter()
    for i in range(n_bcast):
        net.procs[i % n].broadcast(("m", i))
        net.run(until=net.time + 0.7)
    net.run()
    wall = time.perf_counter() - t0
    rep = check_trace(net.trace, all_pids=set(range(n)))
    assert rep.ok, rep.summary()
    return net, wall, rep


def rows_exact(sizes=(50, 100, 200)):
    out = []
    for n in sizes:
        # broadcasters scale with N so the vector-clock entry count (one
        # per process that EVER broadcast — the paper's N) grows too
        n_bcast = n // 2
        # --- PC-broadcast -------------------------------------------- #
        net, wall, rep = run_broadcasts(
            lambda pid: BoundedPCBroadcast(pid, ping_mode="route"), n,
            n_bcast)
        per_delivery_us = wall / max(rep.n_deliveries, 1) * 1e6
        out.append((f"table1/pc/overhead_bytes/N={n}", per_delivery_us,
                    overhead_per_message(net)))
        space = max(len(p.received) for p in net.procs.values())
        out.append((f"table1/pc/space_entries/N={n}", per_delivery_us,
                    space))

        # --- vector clocks -------------------------------------------- #
        net, wall, rep = run_broadcasts(VCBroadcast, n, n_bcast)
        per_delivery_us = wall / max(rep.n_deliveries, 1) * 1e6
        out.append((f"table1/vc/overhead_bytes/N={n}", per_delivery_us,
                    overhead_per_message(net)))
        comparisons = sum(p.comparisons for p in net.procs.values())
        out.append((f"table1/vc/comparisons_per_delivery/N={n}",
                    per_delivery_us,
                    comparisons / max(rep.n_deliveries, 1)))
        space = max(p.local_space_entries() for p in net.procs.values())
        out.append((f"table1/vc/space_entries/N={n}", per_delivery_us,
                    space))
    return out


def rows_vec(sizes=(1000, 10_000, 50_000), backend: str = "numpy"):
    from repro.core.vecsim import run_vec, static_scenario, vc_overhead_model
    out = []
    for n in sizes:
        m_app = 32
        scn = static_scenario(seed=n, n=n, k=6, m_app=m_app)
        t0 = time.perf_counter()
        res = run_vec(scn, backend=backend)
        wall = time.perf_counter() - t0
        assert res.delivered_frac() == 1.0
        per_delivery_us = wall / max(res.stats.deliveries, 1) * 1e6
        pc_overhead = (res.stats.control_bytes
                       / max(res.stats.sent_messages, 1))
        out.append((f"table1/pc/overhead_bytes/N={n}", per_delivery_us,
                    pc_overhead))
        # received-set entries: every process ends up knowing every id
        out.append((f"table1/pc/space_entries/N={n}", per_delivery_us,
                    m_app))
        vc_bytes, vc_cmp = vc_overhead_model(res)
        out.append((f"table1/vc/overhead_bytes/N={n}", per_delivery_us,
                    vc_bytes))
        out.append((f"table1/vc/comparisons_per_delivery/N={n}",
                    per_delivery_us, vc_cmp))
    return out


def rows(engine: str = "exact", n: int | None = None,
         backend: str = "numpy"):
    if engine == "vec":
        return rows_vec((n,) if n is not None else (1000, 10_000, 50_000),
                        backend=backend)
    return rows_exact((n,) if n is not None else (50, 100, 200))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("exact", "vec"), default="exact")
    ap.add_argument("--n", type=int, default=None,
                    help="single population size (default: engine sweep)")
    ap.add_argument("--backend", choices=("numpy", "jax", "auto"),
                    default="numpy")
    args = ap.parse_args()
    for name, us, derived in rows(args.engine, args.n, args.backend):
        print(f"{name},{us:.2f},{derived:.2f}")


if __name__ == "__main__":
    main()
