"""Training/serving substrate benchmarks on CPU smoke configs:
tokens/s for one train step per arch family + serving tokens/tick.

CSV:  train/<arch>,us_per_step,derived(tokens/s)
      serve/<arch>,us_per_tick,derived(tokens/tick)
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.step import make_train_step

FAMILIES = ["yi-6b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
            "recurrentgemma-9b", "whisper-small"]


def train_row(name: str, b: int = 4, s: int = 128, iters: int = 5):
    cfg = replace(ARCHS[name].smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2)))
    data = SyntheticLM(DataConfig(cfg.vocab_size, s, b))
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model),
                                     jnp.float32)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    batch.update(kw)
    params, opt, m = step(params, opt, batch)      # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(iters):
        params, opt, m = step(params, opt, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / iters
    return (f"train/{name}", dt * 1e6, b * s / dt)


def rows():
    return [train_row(n) for n in FAMILIES]


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.0f},{derived:.0f}")


if __name__ == "__main__":
    main()
