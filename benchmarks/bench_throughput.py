"""Sustained-throughput benchmark for the streaming windowed engine.

Drives the windowed engine through ``repro.api.run`` (a sustained
Poisson/bursty ``RunSpec`` with ``engine="windowed"``) and measures how
much causal broadcast one host can push through a fixed O(N·window) memory
budget — the throughput-scalability axis the monolithic (N, M_total)
engine cannot reach (1M broadcasts at N=10k would need an 80 GB dense
matrix; the window holds it in a few hundred MB).

Reports simulated broadcasts/sec and message-copies (sends)/sec of wall
clock, delivered fraction, mean delivery latency in rounds, the live-
column high-water mark, and the exact buffer bytes the window pinned.
Writes everything to ``BENCH_throughput.json`` (``--out``) and prints
the usual ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python benchmarks/bench_throughput.py \
        --n 10000 --messages 1000000 --rate 1000 --window 16384 \
        --backend jax --out BENCH_throughput.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def run_point(n: int, messages: int, rate: float, window: int, k: int,
              backend: str, topology: str, traffic: str, seg_len: int,
              horizon: int | None, max_delay: int, seed: int) -> dict:
    from dataclasses import replace

    from repro.api import (RunSpec, TopologySpec, TrafficSpec, WindowSpec,
                           build_scenario, run)

    spec = RunSpec(
        protocol="pc", engine="windowed", backend=backend, n=n, seed=seed,
        topology=TopologySpec(kind=topology, k=k, max_delay=max_delay),
        traffic=TrafficSpec(kind=traffic, rate=rate, messages=messages),
        window=WindowSpec(window=window, seg_len=seg_len, horizon=horizon,
                          collect="aggregate"))
    t0 = time.perf_counter()
    scn = build_scenario(spec.validate())
    build_s = time.perf_counter() - t0
    # hand the prebuilt scenario back so the report's wall clock is pure
    # engine time, with the build cost reported separately
    rep = run(replace(spec, scenario=scn))
    res, run_s = rep.result, rep.wall_seconds
    if horizon is None:
        # without a horizon the windowed engine is exact: anything less
        # than full delivery is a correctness regression, not a number
        assert not res.expired.any(), "columns expired without a horizon"
        assert rep.delivered_frac == 1.0, \
            f"windowed run did not quiesce ({rep.delivered_frac:.6f})"
    buffer_bytes = 2 * n * window * 4          # arr + delivered, int32
    return dict(
        n=n, k=k, messages=messages, rate=rate, window=window,
        backend=rep.backend, topology=topology, traffic=traffic,
        seg_len=seg_len, horizon=horizon, rounds=scn.rounds,
        build_seconds=round(build_s, 3),
        run_seconds=round(run_s, 3),
        msgs_per_sec=round(messages / run_s, 1),
        sends=res.stats.sent_messages,
        sends_per_sec=round(res.stats.sent_messages / run_s, 1),
        deliveries=res.stats.deliveries,
        delivered_frac=round(rep.delivered_frac, 6),
        mean_latency_rounds=round(rep.mean_latency, 3),
        peak_live=res.peak_live,
        expired=int(res.expired.sum()),
        window_buffer_bytes=buffer_bytes,
    )


def rows(n: int = 5000, messages: int = 100_000, rate: float = 500.0,
         window: int = 8192, k: int = 8, backend: str = "auto",
         topology: str = "kregular", traffic: str = "poisson",
         seg_len: int = 8, horizon: int | None = None, max_delay: int = 1,
         seed: int = 0, out: str | None = None):
    point = run_point(n, messages, rate, window, k, backend, topology,
                      traffic, seg_len, horizon, max_delay, seed)
    if out:
        from repro.obs.report import write_bench_report
        write_bench_report(out, "throughput", point)
    us = point["run_seconds"] * 1e6
    tag = f"n={n},m={messages}"
    return [
        (f"throughput/msgs_per_sec/{tag}", us, point["msgs_per_sec"]),
        (f"throughput/sends_per_sec/{tag}", us, point["sends_per_sec"]),
        (f"throughput/delivered_frac/{tag}", us, point["delivered_frac"]),
        (f"throughput/latency_rounds/{tag}", us, point["mean_latency_rounds"]),
        (f"throughput/peak_live/{tag}", us, float(point["peak_live"])),
        (f"throughput/buffer_mb/{tag}", us,
         point["window_buffer_bytes"] / 2 ** 20),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--messages", type=int, default=100_000)
    ap.add_argument("--rate", type=float, default=500.0,
                    help="mean broadcasts per lockstep round")
    ap.add_argument("--window", type=int, default=8192,
                    help="live message columns (memory = 8·N·window bytes)")
    ap.add_argument("--k", type=int, default=8, help="out-links per process")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas", "auto"),
                    default="auto",
                    help="jax is the fast path for sustained runs: the "
                    "jitted segment scan fuses the per-round masks that "
                    "dominate at large N·window")
    ap.add_argument("--topology", choices=("kregular", "ring", "smallworld"),
                    default="kregular")
    ap.add_argument("--traffic", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--seg-len", type=int, default=8,
                    help="rounds per jitted segment between retirements")
    ap.add_argument("--horizon", type=int, default=None,
                    help="force-retire columns older than this many rounds")
    ap.add_argument("--max-delay", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_throughput.json")
    args = ap.parse_args()
    for name, us, derived in rows(args.n, args.messages, args.rate,
                                  args.window, args.k, args.backend,
                                  args.topology, args.traffic, args.seg_len,
                                  args.horizon, args.max_delay, args.seed,
                                  args.out):
        print(f"{name},{us:.0f},{derived:.3f}")


if __name__ == "__main__":
    main()
