"""Fig. 7 reproduction: transmission-delay sweep on a dynamic overlay —
mean shortest path over safe links (PC) vs all links, and unsafe links /
buffered messages per process — through the one front door
(``repro.api.run``) on either engine.

Both engines now run the *same* churn scenario family (batched link
add/remove schedules racing app traffic), so the rows are directly
comparable:

  * ``exact`` — the discrete-event simulator at N=300 (default): every
    open/close flows through the real ``PCBroadcast`` processes, the run
    is oracle-checked, and the graph metrics come from a mid-run
    snapshot captured at the last churn round;
  * ``vec``   — the vectorized lockstep engine at N=50,000 (default):
    the same sweep at the population sizes the paper's scalability claim
    is about.  ``--window`` routes execution through the streaming
    windowed engine (O(N·window) memory).

Transmission delay maps to link delay in rounds; metrics are taken from
a state snapshot at the end of the churn window, where gating is
busiest.

CSV:  fig7/<metric>/delay=<d>,us_per_call,derived
"""

from __future__ import annotations

import argparse

from repro.api import (DynamicsSpec, MetricsSpec, RunSpec, TopologySpec,
                       TrafficSpec, WindowSpec, run)
from repro.obs import mean_shortest_path
from repro.core.vecsim import (full_out_mask, mean_shortest_path_vec,
                               safe_out_mask, unsafe_link_stats_vec)


def _spec(engine: str, n: int, k: int, delay: int, m_app: int, churn: int,
          backend: str = "numpy", window: int | None = None,
          oracle: bool = False) -> RunSpec:
    return RunSpec(
        protocol="pc", engine=engine, backend=backend, n=n,
        seed=3 + delay,
        topology=TopologySpec(kind="ring", k=k, max_delay=delay),
        traffic=TrafficSpec(kind="uniform", messages=m_app),
        dynamics=DynamicsSpec(kind="churn", n_adds=churn, n_rms=churn,
                              churn_window=16),
        window=WindowSpec(window=window),
        metrics=MetricsSpec(snapshot="last_churn", oracle=oracle))


def rows_exact(n: int = 300, m_app: int = 12, churn: int = 24):
    """The churn sweep on the event simulator: real processes, every
    open/close through Algorithm 2's ping phase, oracle-checked."""
    out = []
    for delay in (1, 2, 3, 4, 5):
        rep = run(_spec("exact", n, k=16, delay=delay, m_app=m_app,
                        churn=churn, oracle=True))
        assert rep.oracle.causal_ok and not rep.oracle.double_deliveries, \
            rep.oracle.summary()
        graphs = rep.result.snapshot_graphs
        srcs = list(range(0, n, max(1, n // 10)))
        sp_safe = mean_shortest_path(graphs["safe"], srcs,
                                     unreachable_penalty=float(n))
        sp_all = mean_shortest_path(graphs["full"], srcs,
                                    unreachable_penalty=float(n))
        unsafe, buffered, _ = graphs["unsafe"]
        wall = rep.wall_seconds * 1e6
        out.append((f"fig7/sp_safe/delay={delay}", wall, sp_safe))
        out.append((f"fig7/sp_all/delay={delay}", wall, sp_all))
        out.append((f"fig7/unsafe_links/delay={delay}", wall, unsafe))
        out.append((f"fig7/buffered_msgs/delay={delay}", wall, buffered))
    return out


def rows_vec(n: int = 50_000, backend: str = "numpy", m_app: int = 12,
             churn: int = 128, window: int | None = None):
    """The same sweep on the vectorized engine at large N.  ``window``
    routes execution through the streaming windowed engine; the snapshot
    then carries the live buffer and its ``is_app`` mask, which the
    metrics consume transparently."""
    out = []
    k = 17                    # ~ the paper's Fig. 7 links/process
    for delay in (1, 2, 3, 4, 5):
        rep = run(_spec("windowed" if window else "vec", n, k=k,
                        delay=delay, m_app=m_app, churn=churn,
                        backend=backend, window=window))
        assert rep.delivered_frac == 1.0, "vec run did not quiesce"
        snap = rep.result.snapshot
        snap_t = int(rep.scenario.add_round[-1])
        wall = rep.wall_seconds * 1e6
        srcs = list(range(0, n, max(1, n // 10)))
        sp_safe = mean_shortest_path_vec(
            snap["adj"], safe_out_mask(snap), srcs,
            unreachable_penalty=float(n))
        sp_all = mean_shortest_path_vec(
            snap["adj"], full_out_mask(snap), srcs,
            unreachable_penalty=float(n))
        unsafe, buffered, _ = unsafe_link_stats_vec(snap, snap_t,
                                                    rep.m_app)
        out.append((f"fig7/sp_safe/delay={delay}", wall, sp_safe))
        out.append((f"fig7/sp_all/delay={delay}", wall, sp_all))
        out.append((f"fig7/unsafe_links/delay={delay}", wall, unsafe))
        out.append((f"fig7/buffered_msgs/delay={delay}", wall, buffered))
    return out


def rows(engine: str = "exact", n: int | None = None,
         backend: str = "numpy", window: int | None = None):
    if engine == "vec":
        return rows_vec(n if n is not None else 50_000, backend=backend,
                        window=window)
    return rows_exact(n if n is not None else 300)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("exact", "vec"), default="exact")
    ap.add_argument("--n", type=int, default=None,
                    help="processes (default: 300 exact / 50000 vec)")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas", "auto"),
                    default="numpy",
                    help="vec-engine backend (numpy is fastest on CPU; "
                         "jax is the accelerator/sharding path)")
    ap.add_argument("--window", type=int, default=None,
                    help="run the vec sweep through the streaming "
                         "windowed engine with this many live columns")
    args = ap.parse_args()
    for name, us, derived in rows(args.engine, args.n, args.backend,
                                  args.window):
        print(f"{name},{us:.0f},{derived:.3f}")


if __name__ == "__main__":
    main()
