"""Fig. 7 reproduction: transmission-delay sweep on a Spray-like dynamic
overlay — mean shortest path over safe links (PC) vs all links (R), and
unsafe links / buffered messages per process.

CSV:  fig7/<metric>/delay=<d>,us_per_call,derived
"""

from __future__ import annotations

import time

from repro.core import BoundedPCBroadcast, Network, SprayOverlay, \
    check_trace, ring_plus_random
from repro.core.metrics import (full_graph, mean_shortest_path, safe_graph,
                                unsafe_link_stats)


def rows(n: int = 300, horizon: float = 90.0):
    out = []
    for delay in (0.5, 1.0, 2.0, 3.0, 5.0):
        net = Network(seed=3, default_delay=delay, oob_delay=delay / 2)
        for pid in range(n):
            net.add_process(BoundedPCBroadcast(
                pid, ping_mode="route", max_size=256, max_retry=8,
                ping_timeout=12 * delay))
        ring_plus_random(net, range(n), k=16)
        overlay = SprayOverlay(net, range(n), period=60.0)
        overlay.start()
        t0 = time.perf_counter()
        # light app traffic so buffers fill during phases
        for t in range(10, int(horizon), 10):
            net.run(until=float(t))
            net.procs[t % n].broadcast(("m", t))
        net.run(until=horizon)
        wall = (time.perf_counter() - t0) * 1e6
        srcs = list(range(0, n, max(1, n // 10)))
        sp_safe = mean_shortest_path(safe_graph(net), srcs,
                                     unreachable_penalty=float(n))
        sp_all = mean_shortest_path(full_graph(net), srcs,
                                    unreachable_penalty=float(n))
        unsafe, buffered, maxbuf = unsafe_link_stats(net)
        overlay.stop()
        net.run(until=net.time + 200 * delay)
        rep = check_trace(net.trace, check_agreement=False)
        assert rep.causal_ok and not rep.double_deliveries, rep.summary()
        out.append((f"fig7/sp_safe/delay={delay}", wall, sp_safe))
        out.append((f"fig7/sp_all/delay={delay}", wall, sp_all))
        out.append((f"fig7/unsafe_links/delay={delay}", wall, unsafe))
        out.append((f"fig7/buffered_msgs/delay={delay}", wall, buffered))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.0f},{derived:.3f}")


if __name__ == "__main__":
    main()
