"""Fig. 7 reproduction: transmission-delay sweep on a dynamic overlay —
mean shortest path over safe links (PC) vs all links (R), and unsafe
links / buffered messages per process.

Two engines (``--engine``):

  * ``exact`` — the discrete-event simulator with Spray-like overlay
    dynamics at N=300 (default): every open/close flows through the real
    ``PCBroadcast`` processes and the run is oracle-checked;
  * ``vec``   — the vectorized lockstep engine (``repro.core.vecsim``)
    at N=50,000 (default): the same sweep at the population sizes the
    paper's scalability claim is about, with churn as batched link
    add/remove schedules.  Transmission delay maps to link delay in
    rounds; metrics are taken from a state snapshot at the end of the
    churn window.

CSV:  fig7/<metric>/delay=<d>,us_per_call,derived
"""

from __future__ import annotations

import argparse
import time

from repro.core import BoundedPCBroadcast, Network, SprayOverlay, \
    check_trace, ring_plus_random
from repro.core.metrics import (full_graph, mean_shortest_path, safe_graph,
                                unsafe_link_stats)


def rows_exact(n: int = 300, horizon: float = 90.0):
    out = []
    for delay in (0.5, 1.0, 2.0, 3.0, 5.0):
        net = Network(seed=3, default_delay=delay, oob_delay=delay / 2)
        for pid in range(n):
            net.add_process(BoundedPCBroadcast(
                pid, ping_mode="route", max_size=256, max_retry=8,
                ping_timeout=12 * delay))
        ring_plus_random(net, range(n), k=16)
        overlay = SprayOverlay(net, range(n), period=60.0)
        overlay.start()
        t0 = time.perf_counter()
        # light app traffic so buffers fill during phases
        for t in range(10, int(horizon), 10):
            net.run(until=float(t))
            net.procs[t % n].broadcast(("m", t))
        net.run(until=horizon)
        wall = (time.perf_counter() - t0) * 1e6
        srcs = list(range(0, n, max(1, n // 10)))
        sp_safe = mean_shortest_path(safe_graph(net), srcs,
                                     unreachable_penalty=float(n))
        sp_all = mean_shortest_path(full_graph(net), srcs,
                                    unreachable_penalty=float(n))
        unsafe, buffered, maxbuf = unsafe_link_stats(net)
        overlay.stop()
        net.run(until=net.time + 200 * delay)
        rep = check_trace(net.trace, check_agreement=False)
        assert rep.causal_ok and not rep.double_deliveries, rep.summary()
        out.append((f"fig7/sp_safe/delay={delay}", wall, sp_safe))
        out.append((f"fig7/sp_all/delay={delay}", wall, sp_all))
        out.append((f"fig7/unsafe_links/delay={delay}", wall, unsafe))
        out.append((f"fig7/buffered_msgs/delay={delay}", wall, buffered))
    return out


def rows_vec(n: int = 50_000, backend: str = "numpy", m_app: int = 12,
             churn: int = 128, window: int | None = None):
    """The same sweep on the vectorized engine at large N.  Integer link
    delays 1..5 rounds stand in for the transmission-delay axis; the
    snapshot is taken at the last churn round, where gating is busiest.
    ``window`` routes execution through the streaming windowed engine
    (O(N·window) memory); the snapshot then carries the live buffer and
    its ``is_app`` mask, which the metrics consume transparently."""
    from repro.core.vecsim import (churn_scenario, full_out_mask,
                                   mean_shortest_path_vec, run_vec,
                                   safe_out_mask, unsafe_link_stats_vec)
    out = []
    k = 17                    # ~ the paper's Fig. 7 links/process
    for delay in (1, 2, 3, 4, 5):
        scn = churn_scenario(seed=3 + delay, n=n, k=k, m_app=m_app,
                             n_adds=churn, n_rms=churn, max_delay=delay,
                             churn_window=16)
        snap = int(scn.add_round[-1]) if scn.n_adds else scn.rounds // 2
        t0 = time.perf_counter()
        res = run_vec(scn, backend=backend, snapshot_round=snap,
                      window=window)
        wall = (time.perf_counter() - t0) * 1e6
        assert res.delivered_frac() == 1.0, "vec run did not quiesce"
        srcs = list(range(0, n, max(1, n // 10)))
        sp_safe = mean_shortest_path_vec(
            res.snapshot["adj"], safe_out_mask(res.snapshot), srcs,
            unreachable_penalty=float(n))
        sp_all = mean_shortest_path_vec(
            res.snapshot["adj"], full_out_mask(res.snapshot), srcs,
            unreachable_penalty=float(n))
        unsafe, buffered, _ = unsafe_link_stats_vec(res.snapshot, snap,
                                                    scn.m_app)
        out.append((f"fig7/sp_safe/delay={delay}", wall, sp_safe))
        out.append((f"fig7/sp_all/delay={delay}", wall, sp_all))
        out.append((f"fig7/unsafe_links/delay={delay}", wall, unsafe))
        out.append((f"fig7/buffered_msgs/delay={delay}", wall, buffered))
    return out


def rows(engine: str = "exact", n: int | None = None,
         backend: str = "numpy", window: int | None = None):
    if engine == "vec":
        return rows_vec(n if n is not None else 50_000, backend=backend,
                        window=window)
    return rows_exact(n if n is not None else 300)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("exact", "vec"), default="exact")
    ap.add_argument("--n", type=int, default=None,
                    help="processes (default: 300 exact / 50000 vec)")
    ap.add_argument("--backend", choices=("numpy", "jax", "auto"),
                    default="numpy",
                    help="vec-engine backend (numpy is fastest on CPU; "
                         "jax is the accelerator/sharding path)")
    ap.add_argument("--window", type=int, default=None,
                    help="run the vec sweep through the streaming "
                         "windowed engine with this many live columns")
    args = ap.parse_args()
    for name, us, derived in rows(args.engine, args.n, args.backend,
                                  args.window):
        print(f"{name},{us:.0f},{derived:.3f}")


if __name__ == "__main__":
    main()
