"""Telemetry overhead gate: bench_scale bare vs obs-off vs obs-on
(S9/DESIGN §2.10 overhead policy).

Runs the sharded-engine scale point four times through
``bench_scale.run_point`` in one process — ``bare`` (no obs spec),
``disabled`` (``ObsSpec(histograms=False)``), ``enabled`` (latency
histograms + span recording) and ``audited`` (enabled + 1-in-32
provenance sampling with the online causality auditor in ``log``
mode) — and reports the steady-state send rates plus their ratios.

The api resolves an all-off ObsSpec to engine ``obs=None``
(``_resolve_obs``), so the disabled arm runs the *identical* engine
program as bare: the "disabled costs <= 2%" budget is met structurally,
and the measured bare/disabled pair doubles as the in-process
repeatability reading that makes the 2% assertion meaningful rather
than vacuous.

The CI gate (``--assert-gate``) compares the arms against each other,
*in-process*, so the 2%/10% budgets measure telemetry plumbing rather
than process-to-process machine variance (which is routinely larger
than 2% even on an idle box):

    disabled >= 0.98 x bare        (obs-off must cost nothing)
    enabled  >= 0.90 x disabled    (obs-on within 10%)
    audited  >= 0.85 x enabled     (flight recorder + auditor within
                                    15% of plain telemetry)

``--floor-ref`` additionally anchors the bare arm on an external
bare-engine report — in CI the nightly scale smoke's fresh
``BENCH_scale_nightly.json``, same config, same runner, minutes
earlier — as a coarser sanity check that the in-process baseline
itself is healthy (20% slack: same-host thermal drift between the two
processes is real):

    bare >= 0.80 x anchor          (anchor = --anchor-frac x ref rate)

    python benchmarks/bench_obs_overhead.py --n 262144 --devices 4 \
        --assert-gate --floor-ref BENCH_scale_nightly.json

Anchoring on a snapshot from different hardware (e.g. the committed
N=1M ``BENCH_scale.json``) needs ``--anchor-frac`` < 1 to absorb the
cross-machine gap.

Writes ``BENCH_obs_overhead.json`` (``--out``) through the shared
versioned report writer (kind ``obs_overhead``).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

DISABLED_FRAC = 0.98   # telemetry off: within 2% of in-process bare
ENABLED_FRAC = 0.90    # telemetry on: within 10% of the disabled arm
AUDITED_FRAC = 0.85    # flight recorder + auditor: within 15% of enabled
BARE_FRAC = 0.80       # in-process bare: within 20% of the anchor


def rows(n: int = 1 << 18, devices: int = 4, messages: int = 256,
         rate: float = 4.0, window: int = 128, k: int = 4,
         seg_len: int = 32, seed: int = 0, scan: str = "auto",
         out: str | None = None):
    from bench_scale import run_point, steady_rate

    from repro.api import ObsSpec

    points = {}
    for label, obs in (("bare", None),
                       ("disabled", ObsSpec(histograms=False)),
                       ("enabled", ObsSpec(histograms=True, spans=True)),
                       ("audited", ObsSpec(histograms=True, spans=True,
                                           provenance=32, audit="log"))):
        point, _ = run_point(n, devices, messages, rate, window, k,
                             "kregular", "poisson", seg_len, None, 1,
                             seed, scan, obs=obs)
        points[label] = point
    bare = steady_rate(points["bare"])
    off = steady_rate(points["disabled"])
    on = steady_rate(points["enabled"])
    aud = steady_rate(points["audited"])
    doc = dict(
        n=n, devices=points["bare"]["devices"], messages=messages,
        rate=rate, window=window, seg_len=seg_len, scan=scan,
        sends_per_sec_steady_bare=bare,
        sends_per_sec_steady_disabled=off,
        sends_per_sec_steady_enabled=on,
        sends_per_sec_steady_audited=aud,
        disabled_over_bare=round(off / bare, 4) if bare else None,
        enabled_over_disabled=round(on / off, 4) if off else None,
        audited_over_enabled=round(aud / on, 4) if on else None,
        points=points)
    if out:
        from repro.obs.report import write_bench_report
        write_bench_report(out, "obs_overhead", doc)
    us = sum(points[p]["run_seconds"] for p in points) * 1e6
    tag = f"n={n},d={doc['devices']}"
    return doc, [
        (f"obs/sends_per_sec_bare/{tag}", us, bare),
        (f"obs/sends_per_sec_disabled/{tag}", us, off),
        (f"obs/sends_per_sec_enabled/{tag}", us, on),
        (f"obs/disabled_over_bare/{tag}", us,
         doc["disabled_over_bare"] or 0.0),
        (f"obs/sends_per_sec_audited/{tag}", us, aud),
        (f"obs/enabled_over_disabled/{tag}", us,
         doc["enabled_over_disabled"] or 0.0),
        (f"obs/audited_over_enabled/{tag}", us,
         doc["audited_over_enabled"] or 0.0),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 18)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--no-force-host", action="store_true",
                    help="do not force host platform devices")
    ap.add_argument("--messages", type=int, default=256)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--seg-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scan", choices=("auto", "on", "off"),
                    default="auto")
    ap.add_argument("--out", default="BENCH_obs_overhead.json")
    ap.add_argument("--assert-gate", action="store_true",
                    help="fail unless disabled >= 0.98x in-process "
                         "bare, enabled >= 0.90x disabled, audited >= "
                         "0.85x enabled, and (with --floor-ref) bare "
                         ">= 0.80x the anchor")
    ap.add_argument("--floor-ref", default=None,
                    help="bare-engine scale report sanity-anchoring "
                         "the in-process bare arm (CI: the nightly "
                         "smoke's fresh same-config measurement)")
    ap.add_argument("--anchor-frac", type=float, default=1.0,
                    help="scale the floor-ref anchor (< 1 when the ref "
                         "came from other hardware)")
    args = ap.parse_args()
    # the forced-host-device flag must land before jax initializes
    if not args.no_force_host and args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    anchor = None
    if args.floor_ref:
        if not os.path.exists(args.floor_ref):
            # the nightly smoke may not have produced its report (first
            # run on a fresh runner, or the smoke itself was skipped):
            # degrade to the in-process-only gate instead of a KeyError
            # deep inside the report loader
            print(f"floor-ref {args.floor_ref!r} not found; skipping "
                  "the bare-arm anchor check (in-process ratios still "
                  "gated)", file=sys.stderr)
        else:
            from bench_scale import steady_rate

            from repro.obs.report import load_bench_report
            ref = load_bench_report(args.floor_ref, kind="scale")
            anchor = args.anchor_frac * steady_rate(ref)
    doc, csv = rows(args.n, args.devices, args.messages, args.rate,
                    args.window, args.k, args.seg_len, args.seed,
                    args.scan, args.out)
    for name, us, derived in csv:
        print(f"{name},{us:.0f},{derived:.3f}")
    if args.assert_gate:
        bare = doc["sends_per_sec_steady_bare"]
        off = doc["sends_per_sec_steady_disabled"]
        on = doc["sends_per_sec_steady_enabled"]
        aud = doc["sends_per_sec_steady_audited"]
        bad = []
        if anchor is not None and bare < BARE_FRAC * anchor:
            bad.append(f"bare {bare:.0f} < {BARE_FRAC * anchor:.0f} "
                       f"({BARE_FRAC:.0%} of anchor {anchor:.0f})")
        if off < DISABLED_FRAC * bare:
            bad.append(f"disabled {off:.0f} < "
                       f"{DISABLED_FRAC * bare:.0f} "
                       f"({DISABLED_FRAC:.0%} of bare {bare:.0f})")
        if on < ENABLED_FRAC * off:
            bad.append(f"enabled {on:.0f} < {ENABLED_FRAC * off:.0f} "
                       f"({ENABLED_FRAC:.0%} of disabled {off:.0f})")
        if aud < AUDITED_FRAC * on:
            bad.append(f"audited {aud:.0f} < {AUDITED_FRAC * on:.0f} "
                       f"({AUDITED_FRAC:.0%} of enabled {on:.0f})")
        if bad:
            print("OVERHEAD GATE VIOLATION: " + "; ".join(bad),
                  file=sys.stderr)
            sys.exit(1)
        print(f"overhead gate ok: bare {bare:.0f}, disabled {off:.0f}, "
              f"enabled {on:.0f}, audited {aud:.0f} sends/s"
              + (f" vs anchor {anchor:.0f}" if anchor else ""))


if __name__ == "__main__":
    main()
