"""Benchmark harness — one module per paper table/figure + substrate
benches.  Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import bench_engine, bench_fig7, bench_table1, \
        bench_train
    print("name,us_per_call,derived")
    failed = 0
    for mod in (bench_table1, bench_fig7, bench_engine, bench_train):
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.2f},{derived:.3f}", flush=True)
        except Exception:                      # noqa: BLE001
            failed += 1
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
