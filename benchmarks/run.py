"""Benchmark harness — one module per paper table/figure + substrate
benches.  Prints ``name,us_per_call,derived`` CSV.

``--engine exact`` (default) runs the paper-scale reproductions on the
discrete-event simulator; ``--engine vec`` runs the Table 1 / Fig. 7
sweeps on the vectorized lockstep engine at large N (``--n`` overrides
the population) plus the sustained-throughput bench of the streaming
windowed engine; ``--engine both`` runs the two back to back.
``--window`` routes every vec-engine sweep through the streaming
windowed engine with that many live columns.  ``--scale-devices D``
additionally runs a harness-sized point of the device-sharded scale
bench (``bench_scale``) on a D-device mesh.  The substrate benches
(engine/train) are engine-independent and always run.  All protocol
benches dispatch through ``repro.api.run`` (one spec, one front door).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

# allow `python benchmarks/run.py` from the repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("exact", "vec", "both"),
                    default="exact")
    ap.add_argument("--n", type=int, default=None,
                    help="population override for the protocol benches")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas", "auto"),
                    default="numpy", help="vec-engine backend")
    ap.add_argument("--compare-backends", action="store_true",
                    help="also run a harness-sized jax-vs-pallas point "
                         "(full run: benchmarks/bench_backend.py)")
    ap.add_argument("--window", type=int, default=None,
                    help="route the vec sweeps (and the throughput "
                         "bench) through the streaming windowed engine "
                         "with this many live columns")
    ap.add_argument("--scale-devices", type=int, default=None,
                    help="also run a harness-sized sharded scale point "
                         "on this many devices (forces host platform "
                         "devices; full run: benchmarks/bench_scale.py)")
    ap.add_argument("--serve", action="store_true",
                    help="also run a harness-sized live-serving capacity "
                         "sweep (open-loop ingest; honors --scale-devices "
                         "and --scan; full run: benchmarks/bench_serve.py)")
    ap.add_argument("--scan", choices=("auto", "on", "off"), default="auto",
                    help="sharded segment stepping for --serve/"
                         "--scale-devices points")
    args = ap.parse_args()
    if args.scale_devices and args.engine == "exact":
        print("warning: --scale-devices runs with the vec benches only; "
              "pass --engine vec or --engine both", file=sys.stderr)
    if args.scale_devices and args.scale_devices > 1:
        # must precede jax initialization (the bench modules import jax
        # lazily, so setting it here is early enough from the CLI)
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.scale_devices}").strip()
    # imported after the device-count env var so it precedes jax init
    from benchmarks import bench_backend, bench_engine, bench_fig7, \
        bench_scale, bench_serve, bench_table1, bench_throughput, \
        bench_train
    engines = ("exact", "vec") if args.engine == "both" else (args.engine,)

    print("name,us_per_call,derived")
    failed = 0
    for eng in engines:
        # keep the historical row names in single-engine runs; disambiguate
        # with an engine prefix only when both engines emit the same rows
        prefix = f"{eng}/" if len(engines) > 1 else ""
        # in "both" mode a large --n meant for the vec engine would drive
        # the event simulator far past its ~2k ceiling — vec only there
        n = args.n if (eng == "vec" or len(engines) == 1) else None
        for mod in (bench_table1, bench_fig7):
            try:
                for name, us, derived in mod.rows(engine=eng, n=n,
                                                  backend=args.backend,
                                                  window=args.window):
                    print(f"{prefix}{name},{us:.2f},{derived:.3f}",
                          flush=True)
            except Exception:                  # noqa: BLE001
                failed += 1
                traceback.print_exc()
        if eng == "vec":
            # sustained throughput is windowed-engine-specific: a
            # harness-sized point (the nightly CI smoke runs the big one)
            try:
                for name, us, derived in bench_throughput.rows(
                        n=args.n if args.n is not None else 2000,
                        messages=20_000, rate=200.0,
                        window=args.window if args.window else 4096,
                        backend=args.backend, seg_len=8, out=None):
                    print(f"{prefix}{name},{us:.2f},{derived:.3f}",
                          flush=True)
            except Exception:                  # noqa: BLE001
                failed += 1
                traceback.print_exc()
        if eng == "vec" and args.compare_backends:
            try:
                for name, us, derived in bench_backend.rows(
                        n=256, messages=512, rate=16.0, window=128,
                        seg_len=8, out=None):
                    print(f"{prefix}{name},{us:.2f},{derived:.3f}",
                          flush=True)
            except Exception:                  # noqa: BLE001
                failed += 1
                traceback.print_exc()
        if eng == "vec" and args.scale_devices:
            try:
                _, csv = bench_scale.rows(
                    n=args.n if args.n is not None else 65536,
                    devices=args.scale_devices, messages=128,
                    rate=4.0, window=64, seg_len=8, out=None,
                    scan=args.scan)
                for name, us, derived in csv:
                    print(f"{prefix}{name},{us:.2f},{derived:.3f}",
                          flush=True)
            except Exception:                  # noqa: BLE001
                failed += 1
                traceback.print_exc()
        if eng == "vec" and args.serve:
            # live serving capacity: a harness-sized two-rate sweep (the
            # nightly CI smoke runs the full bench_serve sweep)
            try:
                _, csv = bench_serve.rows(
                    n=args.n if args.n is not None else 4096,
                    devices=args.scale_devices,
                    engine="sharded" if args.scale_devices else "auto",
                    scan=args.scan, rates=(4.0, 16.0), messages=2000,
                    window=args.window, seg_len=8, out=None)
                for name, us, derived in csv:
                    print(f"{prefix}{name},{us:.2f},{derived:.3f}",
                          flush=True)
            except Exception:                  # noqa: BLE001
                failed += 1
                traceback.print_exc()
    for mod in (bench_engine, bench_train):
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.2f},{derived:.3f}", flush=True)
        except Exception:                      # noqa: BLE001
            failed += 1
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
