"""Serving-capacity benchmark: the offered-rate vs p99-SLO knee.

Sweeps an open-loop offered rate (submissions per simulated round)
through ``repro.api`` live mode and reports, per rate point, the
measured rounds-to-delivery percentiles (queueing delay included), the
sustained wall-clock requests/s, and whether the p99 met the SLO.  The
*knee* — the highest offered rate whose p99 still meets the SLO — is
the headline: ``capacity_rate`` (simulated load the service can absorb)
and ``capacity_req_per_s`` (the wall-clock ingest rate it sustained
there).  On a multi-device mesh the process axis shards exactly as in
``bench_scale``; forced host devices are set up here when needed::

    PYTHONPATH=src python benchmarks/bench_serve.py \
        --n 65536 --devices 4 --messages 20000 --rates 4,8,16,32

Writes ``BENCH_serve.json`` (``--out``) and prints the usual
``name,us_per_call,derived`` CSV rows.  CI regression floor:
``--assert-floor 0.5 --floor-ref BENCH_serve.json`` fails the run when
the knee's sustained requests/s drops more than 50% below the committed
reference on the same host class.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def run_point(n: int, devices: int | None, engine: str, scan: str,
              arrivals: str, admission: str, rate: float, messages: int,
              window: int | None, queue_cap: int, seg_len: int,
              slo_p99: float, k: int, topology: str, max_delay: int,
              seed: int, period: int, duty: float,
              rate_lo: float | None = None) -> dict:
    from repro.api import (LiveSpec, RunSpec, ShardSpec, TopologySpec,
                           WindowSpec, run)

    spec = RunSpec(
        protocol="pc", mode="live", engine=engine, n=n, seed=seed,
        shard=ShardSpec(devices=devices, scan=scan),
        topology=TopologySpec(kind=topology, k=k, max_delay=max_delay),
        window=WindowSpec(window=window, seg_len=seg_len,
                          collect="aggregate"),
        live=LiveSpec(arrivals=arrivals, admission=admission, rate=rate,
                      messages=messages, queue_cap=queue_cap,
                      slo_p99=slo_p99, period=period, duty=duty,
                      rate_lo=rate_lo))
    rep = run(spec)
    lr = rep.live
    assert lr.admitted + lr.shed_queue + lr.shed_policy \
        + lr.unserved == lr.offered, "serve accounting leak"
    return dict(
        rate=rate, offered=lr.offered, admitted=lr.admitted,
        shed=lr.shed_queue + lr.shed_policy, unserved=lr.unserved,
        rounds=lr.rounds, ticks=lr.ticks_run,
        engine=rep.engine, window=rep.window,
        wall_seconds=round(lr.wall_seconds, 3),
        req_per_s=round(lr.requests_per_sec, 1),
        p50=round(lr.p50, 2), p99=round(lr.p99, 2),
        p999=round(lr.p999, 2),
        mean_latency_rounds=round(lr.mean_latency_rounds, 2),
        queue_peak=lr.queue_peak,
        backpressure_ticks=lr.backpressure_ticks,
        overflow_catches=lr.overflow_catches,
        delivered_frac=round(lr.delivered_frac, 6),
        slo_ok=bool(lr.slo_ok),
    )


def capacity(doc: dict) -> float:
    """The comparable headline of a bench snapshot: sustained wall-clock
    requests/s at the knee (0.0 when no rate point met the SLO)."""
    return float(doc.get("capacity_req_per_s") or 0.0)


def rows(n: int = 1 << 16, devices: int | None = None,
         engine: str = "auto", scan: str = "auto",
         arrivals: str = "poisson", admission: str = "defer",
         rates: tuple = (4.0, 8.0, 16.0, 32.0), messages: int = 20000,
         window: int | None = None, queue_cap: int = 1 << 16,
         seg_len: int = 32, slo_p99: float = 256.0, k: int = 4,
         topology: str = "kregular", max_delay: int = 1, seed: int = 0,
         period: int = 256, duty: float = 0.25,
         rate_lo: float | None = None, out: str | None = None):
    points = []
    for rate in rates:
        t0 = time.perf_counter()
        p = run_point(n, devices, engine, scan, arrivals, admission,
                      rate, messages, window, queue_cap, seg_len,
                      slo_p99, k, topology, max_delay, seed, period,
                      duty, rate_lo)
        p["point_seconds"] = round(time.perf_counter() - t0, 3)
        points.append(p)
    ok = [p for p in points if p["slo_ok"]]
    knee = max(ok, key=lambda p: p["rate"]) if ok else None
    eng = points[0]["engine"]
    doc = dict(
        n=n,
        devices=(devices if devices is not None
                 else ("all" if eng == "sharded" else 1)),
        engine=eng, arrivals=arrivals,
        admission=admission, messages=messages, slo_p99=slo_p99,
        period=period, duty=duty, rate_lo=rate_lo,
        seg_len=seg_len, window=points[0]["window"],
        capacity_rate=knee["rate"] if knee else None,
        capacity_req_per_s=knee["req_per_s"] if knee else None,
        capacity_p99=knee["p99"] if knee else None,
        points=points)
    if out:
        from repro.obs.report import write_bench_report
        write_bench_report(out, "serve", doc)
    csv = []
    for p in points:
        tag = f"n={n},rate={p['rate']:g}"
        us = p["wall_seconds"] * 1e6
        csv += [(f"serve/p99_rounds/{tag}", us, p["p99"]),
                (f"serve/req_per_s/{tag}", us, p["req_per_s"]),
                (f"serve/slo_ok/{tag}", us, float(p["slo_ok"]))]
    csv.append((f"serve/capacity_req_per_s/n={n}",
                sum(p["wall_seconds"] for p in points) * 1e6,
                capacity(doc)))
    return doc, csv


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 16,
                    help="processes (default 65,536)")
    ap.add_argument("--devices", type=int, default=None,
                    help="device-mesh size (engine 'sharded'); default: "
                         "single host, engine auto-selected")
    ap.add_argument("--no-force-host", action="store_true",
                    help="do not force host platform devices (use this "
                         "on a real accelerator mesh)")
    ap.add_argument("--engine", choices=("auto", "windowed", "sharded"),
                    default="auto")
    ap.add_argument("--scan", choices=("auto", "on", "off"),
                    default="auto")
    ap.add_argument("--arrivals", default="poisson",
                    help="arrival process (poisson | bursty | diurnal)")
    ap.add_argument("--admission", default="defer",
                    help="admission policy (defer | shed | admit)")
    ap.add_argument("--rates", default="4,8,16,32",
                    help="comma-separated offered rates (msgs per "
                         "simulated round) to sweep")
    ap.add_argument("--messages", type=int, default=20000,
                    help="submissions offered per rate point")
    ap.add_argument("--window", type=int, default=None,
                    help="live columns; default: memory-budget rule")
    ap.add_argument("--queue-cap", type=int, default=1 << 16)
    ap.add_argument("--seg-len", type=int, default=32)
    ap.add_argument("--slo-p99", type=float, default=256.0,
                    help="p99 rounds-to-delivery SLO defining the knee")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--topology",
                    choices=("kregular", "ring", "smallworld"),
                    default="kregular")
    ap.add_argument("--max-delay", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--period", type=int, default=256,
                    help="bursty/diurnal period in rounds")
    ap.add_argument("--duty", type=float, default=0.25,
                    help="bursty high-rate fraction of each period")
    ap.add_argument("--rate-lo", type=float, default=None,
                    help="bursty baseline rate (default: rate / 8)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--assert-floor", type=float, default=None,
                    metavar="FRAC",
                    help="fail if the knee's requests/s drops more than "
                         "FRAC below the --floor-ref snapshot")
    ap.add_argument("--floor-ref", default="BENCH_serve.json",
                    help="committed reference snapshot for --assert-floor")
    args = ap.parse_args()
    # forced host devices must land before jax initializes
    if not args.no_force_host and (args.devices or 1) > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    ref = None
    if args.assert_floor is not None:
        # read the reference before --out can overwrite the same file
        from repro.obs.report import load_bench_report
        ref = load_bench_report(args.floor_ref, kind="serve")
    rates = tuple(float(r) for r in args.rates.split(","))
    doc, csv = rows(args.n, args.devices, args.engine, args.scan,
                    args.arrivals, args.admission, rates, args.messages,
                    args.window, args.queue_cap, args.seg_len,
                    args.slo_p99, args.k, args.topology, args.max_delay,
                    args.seed, args.period, args.duty, args.rate_lo,
                    args.out)
    for name, us, derived in csv:
        print(f"{name},{us:.0f},{derived:.3f}")
    if doc["capacity_rate"] is None:
        print("warning: no rate point met the SLO", file=sys.stderr)
    if ref is not None:
        floor = (1.0 - args.assert_floor) * capacity(ref)
        got = capacity(doc)
        if got < floor:
            print(f"FLOOR VIOLATION: capacity req/s {got:.0f} < "
                  f"{floor:.0f} ({(1 - args.assert_floor) * 100:.0f}% of "
                  f"reference {capacity(ref):.0f})", file=sys.stderr)
            sys.exit(1)
        print(f"floor ok: capacity req/s {got:.0f} >= {floor:.0f}")


if __name__ == "__main__":
    main()
