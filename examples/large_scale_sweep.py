"""Breaking the scalability barrier, demonstrated: the same PC-broadcast
churn scenario swept from N=1k to N=100k on the vectorized lockstep
engine (``repro.core.vecsim``), with the exact discrete-event simulator
timed alongside at the small sizes it can still reach.

Per population size the sweep reports wall-clock, simulated message
volume, delivered fraction, mean delivery latency (rounds), peak unsafe
links/process during churn, and — because the protocol's control
information is O(1) — a constant bytes/message column that does not grow
with N (the vector-clock baseline's modeled overhead is printed next to
it for contrast).

    PYTHONPATH=src python examples/large_scale_sweep.py \
        [--sizes 1000 5000 20000 50000] [--exact-max 2000] [--backend numpy]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import BoundedPCBroadcast, Network, check_trace, \
    ring_plus_random
from repro.core.vecsim import (churn_scenario, run_vec, unsafe_link_stats_vec,
                               vc_overhead_model)


def exact_point(n: int, n_bcast: int = 12) -> float:
    """Wall-clock for a comparable broadcast run on the event simulator."""
    net = Network(seed=1, default_delay=1.0, oob_delay=0.5)
    for pid in range(n):
        net.add_process(BoundedPCBroadcast(pid, ping_mode="route"))
    ring_plus_random(net, range(n), k=8)
    t0 = time.perf_counter()
    for i in range(n_bcast):
        net.procs[(i * 13) % n].broadcast(("m", i))
        net.run(until=net.time + 1.0)
    net.run()
    dt = time.perf_counter() - t0
    rep = check_trace(net.trace, check_agreement=False)
    assert rep.causal_ok, rep.summary()
    return dt


def vec_point(n: int, backend: str, window: int | None = None):
    scn = churn_scenario(seed=n, n=n, k=9, m_app=12,
                         n_adds=max(8, n // 400), n_rms=max(8, n // 400),
                         max_delay=2, churn_window=8)
    snap = int(scn.add_round[-1])
    t0 = time.perf_counter()
    res = run_vec(scn, backend=backend, snapshot_round=snap, window=window,
                  collect=None if window is None else "full")
    dt = time.perf_counter() - t0
    unsafe, _, _ = unsafe_link_stats_vec(res.snapshot, snap, scn.m_app)
    pc_bytes = res.stats.control_bytes / max(res.stats.sent_messages, 1)
    vc_bytes, _ = vc_overhead_model(res)
    return dt, res, unsafe, pc_bytes, vc_bytes


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[1000, 5000, 20000, 50000])
    ap.add_argument("--exact-max", type=int, default=2000,
                    help="run the event simulator up to this N for contrast")
    ap.add_argument("--backend", choices=("numpy", "jax", "auto"),
                    default="numpy")
    ap.add_argument("--window", type=int, default=None,
                    help="stream each point through the windowed engine "
                         "with this many live message columns (O(N·window) "
                         "memory; see benchmarks/bench_throughput.py for "
                         "the sustained-traffic story)")
    args = ap.parse_args()

    print(f"{'N':>7} {'vec(s)':>7} {'exact(s)':>9} {'msgs':>11} "
          f"{'frac':>5} {'lat(rd)':>7} {'unsafe/p':>8} "
          f"{'pc B/msg':>8} {'vc B/msg':>8}")
    for n in args.sizes:
        dt, res, unsafe, pc_bytes, vc_bytes = vec_point(n, args.backend,
                                                        args.window)
        exact_s = (f"{exact_point(n):9.1f}" if n <= args.exact_max
                   else f"{'--':>9}")
        assert res.delivered_frac() == 1.0
        print(f"{n:7d} {dt:7.1f} {exact_s} {res.stats.sent_messages:11d} "
              f"{res.delivered_frac():5.2f} {res.mean_latency():7.2f} "
              f"{unsafe:8.4f} {pc_bytes:8.1f} {vc_bytes:8.1f}")
    print("\npc B/msg stays constant while vc B/msg grows with the number "
          "of broadcasters — the paper's Table 1 separation, at scale.")


if __name__ == "__main__":
    main()
