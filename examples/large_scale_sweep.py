"""Breaking the scalability barrier, demonstrated: the same PC-broadcast
churn scenario swept from N=1k to N=100k through the one experiment
front door (``repro.api.run``), with the exact discrete-event simulator
timed alongside at the small sizes it can still reach.

Per population size the sweep reports wall-clock, simulated message
volume, delivered fraction, mean delivery latency (rounds), peak unsafe
links/process during churn, and — because the protocol's control
information is O(1) — a constant bytes/message column that does not grow
with N.  The vector-clock baseline's **measured** overhead (the
vectorized VC protocol run on the same scenario, ``vecsim.vc``) is
printed next to it for contrast.

    PYTHONPATH=src python examples/large_scale_sweep.py \
        [--sizes 1000 5000 20000 50000] [--exact-max 2000] [--backend numpy]
"""

from __future__ import annotations

import argparse

from repro.api import (DynamicsSpec, MetricsSpec, RunSpec, TopologySpec,
                       TrafficSpec, WindowSpec, run)
from repro.core.vecsim import unsafe_link_stats_vec


def _spec(n: int, protocol: str = "pc", engine: str = "vec",
          backend: str = "numpy", window: int | None = None,
          snapshot: bool = True) -> RunSpec:
    return RunSpec(
        protocol=protocol, engine=engine, backend=backend, n=n, seed=n,
        topology=TopologySpec(kind="ring", k=9, max_delay=2),
        traffic=TrafficSpec(kind="uniform", messages=12),
        dynamics=DynamicsSpec(kind="churn", n_adds=max(8, n // 400),
                              n_rms=max(8, n // 400), churn_window=8),
        window=WindowSpec(window=window,
                          collect="full" if window else "auto"),
        metrics=MetricsSpec(snapshot="last_churn" if snapshot else None))


def exact_point(n: int) -> float:
    """Wall-clock for the same scenario on the event simulator."""
    rep = run(_spec(n, engine="exact", snapshot=False))
    assert rep.delivered_frac == 1.0
    return rep.wall_seconds


def vec_point(n: int, backend: str, window: int | None = None):
    rep = run(_spec(n, backend=backend,
                    engine="windowed" if window else "vec", window=window))
    snap_t = int(rep.scenario.add_round[-1])
    unsafe, _, _ = unsafe_link_stats_vec(rep.result.snapshot, snap_t,
                                         rep.m_app)
    pc_bytes = rep.extras["overhead_bytes_per_msg"]
    # the vector-clock baseline, measured on the identical scenario
    rep_vc = run(_spec(n, protocol="vc", snapshot=False))
    assert rep_vc.delivered_frac == 1.0
    return rep, unsafe, pc_bytes, rep_vc.extras["overhead_bytes_per_msg"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[1000, 5000, 20000, 50000])
    ap.add_argument("--exact-max", type=int, default=2000,
                    help="run the event simulator up to this N for contrast")
    ap.add_argument("--backend", choices=("numpy", "jax", "pallas", "auto"),
                    default="numpy")
    ap.add_argument("--window", type=int, default=None,
                    help="stream each point through the windowed engine "
                         "with this many live message columns (O(N·window) "
                         "memory; see benchmarks/bench_throughput.py for "
                         "the sustained-traffic story)")
    args = ap.parse_args()

    print(f"{'N':>7} {'vec(s)':>7} {'exact(s)':>9} {'msgs':>11} "
          f"{'frac':>5} {'lat(rd)':>7} {'unsafe/p':>8} "
          f"{'pc B/msg':>8} {'vc B/msg':>8}")
    for n in args.sizes:
        rep, unsafe, pc_bytes, vc_bytes = vec_point(n, args.backend,
                                                    args.window)
        exact_s = (f"{exact_point(n):9.1f}" if n <= args.exact_max
                   else f"{'--':>9}")
        assert rep.delivered_frac == 1.0
        print(f"{n:7d} {rep.wall_seconds:7.1f} {exact_s} "
              f"{rep.stats.sent_messages:11d} "
              f"{rep.delivered_frac:5.2f} {rep.mean_latency:7.2f} "
              f"{unsafe:8.4f} {pc_bytes:8.1f} {vc_bytes:8.1f}")
    print("\npc B/msg stays constant while vc B/msg grows with the number "
          "of broadcasters — the paper's Table 1 separation, measured at "
          "scale.")


if __name__ == "__main__":
    main()
