"""Quickstart: train a small LM for a few dozen steps on CPU, checkpoint,
resume, and sample from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from dataclasses import replace

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.training.optimizer import AdamWConfig, OptState, init_opt_state
from repro.training.step import make_train_step


def main():
    cfg = replace(get_arch("yi-6b").smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2)))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8))

    print("== training ==")
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    with tempfile.TemporaryDirectory() as d:
        print("== checkpoint / resume ==")
        ckpt.save(d, 40, {"params": params, "opt": opt._asdict()},
                  meta={"data_step": 40})
        state, meta = ckpt.restore(d, 40, like={"params": params,
                                                "opt": opt._asdict()})
        params, opt = state["params"], OptState(**state["opt"])
        for i in range(meta["data_step"], meta["data_step"] + 10):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            params, opt, m = step(params, opt, batch)
        print(f"resumed loss {float(m['loss']):.4f}")

    print("== sampling ==")
    eng = ServingEngine(model, params, ServeConfig(batch=2, max_len=64))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8
                                               ).astype(np.int32),
                    max_new_tokens=12) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        print(f"req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
