"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np
from dataclasses import replace

from repro.configs import get_arch
from repro.models import build_model
from repro.serving.engine import Request, ServeConfig, ServingEngine


def main():
    cfg = replace(get_arch("qwen3-8b").smoke(), compute_dtype="float32",
                  param_dtype="float32")
    model = build_model(cfg, remat="none")
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ServeConfig(batch=4, max_len=96))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        r = Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 20))
                                        ).astype(np.int32),
                    max_new_tokens=12,
                    temperature=0.8 if i % 2 else 0.0)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        mode = "sampled" if r.temperature else "greedy"
        print(f"req {r.rid:2d} ({mode:7s}) -> {r.out_tokens}")
    print(f"\n{tokens} tokens / {eng.ticks} ticks / {dt:.1f}s "
          f"-> {tokens/max(eng.ticks,1):.2f} tokens/tick "
          f"(4 slots, continuous batching)")


if __name__ == "__main__":
    main()
