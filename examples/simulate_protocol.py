"""Reproduce the paper's experiment (Fig. 7) at reduced scale, plus the
vectorized-engine version at 10k processes — both parts one
``repro.api.run(RunSpec)`` call on the same declarative scenario.

Part 1 (exact engine): a churn scenario under a transmission-delay ramp
on the discrete-event simulator; measures mean shortest path over safe
links (PC-broadcast) vs. all links and unsafe links/process from a
mid-churn snapshot, oracle-checked.

Part 2 (vec engine): the same protocol semantics, vectorized, at 10k
processes in seconds on one core — same spec, different ``engine=``.

    PYTHONPATH=src python examples/simulate_protocol.py [--n 300]
"""

from __future__ import annotations

import argparse

from repro.api import (DynamicsSpec, MetricsSpec, RunSpec, TopologySpec,
                       TrafficSpec, run)
from repro.obs import mean_shortest_path
from repro.core.vecsim import (full_out_mask, mean_shortest_path_vec,
                               safe_out_mask, unsafe_link_stats_vec)


def _spec(engine: str, n: int, delay: int, seed: int = 1) -> RunSpec:
    """One churn experiment; only the engine changes between the parts.
    Paper parameterization: ~17 links/process (Spray at 10k procs), so a
    few unsafe links leave the safe graph's diameter almost intact."""
    return RunSpec(
        protocol="pc", engine=engine, n=n, seed=seed,
        topology=TopologySpec(kind="ring", k=16, max_delay=delay),
        traffic=TrafficSpec(kind="uniform", messages=10),
        dynamics=DynamicsSpec(kind="churn",
                              n_adds=max(4, min(64, n // 12)),
                              n_rms=max(4, min(64, n // 12)),
                              churn_window=12),
        metrics=MetricsSpec(snapshot="last_churn", oracle=True))


def part1(n: int):
    print(f"== Fig. 7 (exact engine, N={n}) ==")
    print(f"{'delay':>6} {'sp_safe':>8} {'sp_all':>7} "
          f"{'unsafe/proc':>11} {'buffered':>9} {'wall(s)':>8}")
    srcs = list(range(0, n, max(1, n // 10)))
    for delay in (1, 2, 3, 5):
        rep = run(_spec("exact", n, delay))
        assert rep.oracle.causal_ok and not rep.oracle.double_deliveries, \
            rep.oracle.summary()
        graphs = rep.result.snapshot_graphs
        sp_s = mean_shortest_path(graphs["safe"], srcs,
                                  unreachable_penalty=float(n))
        sp_a = mean_shortest_path(graphs["full"], srcs,
                                  unreachable_penalty=float(n))
        mu, mb, _ = graphs["unsafe"]
        print(f"{delay:6d} {sp_s:8.2f} {sp_a:7.2f} "
              f"{mu:11.3f} {mb:9.3f} {rep.wall_seconds:8.1f}")


def part2(n: int = 10_000):
    print(f"\n== vectorized engine (N={n}) ==")
    rep = run(_spec("vec", n, delay=2))
    assert rep.oracle.ok, rep.oracle.summary()
    assert rep.delivered_frac == 1.0
    snap = rep.result.snapshot
    snap_t = int(rep.scenario.add_round[-1])
    srcs = list(range(0, n, max(1, n // 10)))
    sp_s = mean_shortest_path_vec(snap["adj"], safe_out_mask(snap), srcs,
                                  unreachable_penalty=float(n))
    sp_a = mean_shortest_path_vec(snap["adj"], full_out_mask(snap), srcs,
                                  unreachable_penalty=float(n))
    mu, mb, _ = unsafe_link_stats_vec(snap, snap_t, rep.m_app)
    cells = rep.n * (rep.m_app + rep.scenario.n_adds) * rep.rounds
    print(f"{n} processes x {rep.rounds} rounds in "
          f"{rep.wall_seconds:.1f}s "
          f"({cells / max(rep.wall_seconds, 1e-9) / 1e6:.0f}M "
          f"cell-round updates/s)")
    print(f"delivered={rep.delivered_frac:.3f} "
          f"mean_latency={rep.mean_latency:.2f} rounds  "
          f"sp_safe={sp_s:.2f} sp_all={sp_a:.2f} "
          f"unsafe/proc={mu:.4f} buffered={mb:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=300)
    args = ap.parse_args()
    part1(args.n)
    part2()
