"""Reproduce the paper's experiment (Fig. 7) at reduced scale, plus the
tensorized-engine version at 10k processes.

Part 1 (event core, exact algorithms): a Spray-like dynamic overlay under
a transmission-delay ramp; measures mean shortest path over safe links
(PC-broadcast) vs. all links (R-broadcast) and unsafe links/process.

Part 2 (JAX engine): the same protocol semantics, tensorized, at 10k
processes in seconds on one core.

    PYTHONPATH=src python examples/simulate_protocol.py [--n 300]
"""

import argparse
import statistics

from repro.core import BoundedPCBroadcast, Network, SprayOverlay, \
    check_trace, ring_plus_random
from repro.core.metrics import (full_graph, mean_shortest_path, safe_graph,
                                unsafe_link_stats)


def part1(n: int):
    print(f"== Fig. 7 (event core, N={n}) ==")
    # Paper parameterization: ~17 links/process (Spray at 10k procs), so
    # a few unsafe links leave the safe graph's diameter almost intact.
    net = Network(seed=1,
                  default_delay=lambda t, r: min(0.1 + t / 60.0, 5.0),
                  oob_delay=0.2)
    for pid in range(n):
        net.add_process(BoundedPCBroadcast(
            pid, ping_mode="route", max_size=128, max_retry=8,
            ping_timeout=60.0))
    ring_plus_random(net, range(n), k=16)
    overlay = SprayOverlay(net, range(n), period=60.0)
    overlay.start()
    print(f"{'t(s)':>6} {'delay':>6} {'sp_safe':>8} {'sp_all':>7} "
          f"{'unsafe/proc':>11} {'buffered':>9}")
    for t in range(0, 241, 30):
        net.run(until=float(t))
        if t % 60 == 0 and t > 0:
            net.procs[t % n].broadcast(("probe", t))
        srcs = list(range(0, n, max(1, n // 10)))
        sp_s = mean_shortest_path(safe_graph(net), srcs,
                                  unreachable_penalty=float(n))
        sp_a = mean_shortest_path(full_graph(net), srcs,
                                  unreachable_penalty=float(n))
        mu, mb, _ = unsafe_link_stats(net)
        delay = min(0.1 + t / 60.0, 5.0)
        print(f"{t:6d} {delay:6.2f} {sp_s:8.2f} {sp_a:7.2f} "
              f"{mu:11.2f} {mb:9.2f}")
    overlay.stop()
    net.run(until=net.time + 3000)
    rep = check_trace(net.trace, check_agreement=False)
    print("oracle:", rep.summary())
    assert rep.causal_ok and not rep.double_deliveries


def part2():
    print("\n== tensorized engine (N=10k) ==")
    import time
    from repro.core.engine import analyze, random_instance, run_engine
    cfg, sched, adj0, delay0 = random_instance(
        7, n=10_000, k=8, m_app=64, n_adds=48, n_rms=48, rounds=64,
        mode="pc")
    t0 = time.time()
    d = run_engine(cfg, sched, adj0, delay0)
    dt = time.time() - t0
    rep = analyze(d, sched)
    cell_rounds = d.shape[0] * d.shape[1] * cfg.rounds
    print(f"10k processes x 64 rounds x {sched.m_total} msg slots "
          f"in {dt:.1f}s ({cell_rounds/dt/1e6:.0f}M cell-round updates/s)")
    print(f"violations={rep['violations']} missing={rep['missing']} "
          f"delivered={rep['delivered_frac']:.3f} "
          f"mean_latency={rep['mean_latency']:.2f} rounds")
    assert rep["violations"] == 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=300)
    args = ap.parse_args()
    part1(args.n)
    part2()
