"""End-to-end driver (the paper's kind of system): elastic multi-pod
training over PC-broadcast.

Five pods train DiLoCo-style; outer updates disseminate via the paper's
causal broadcast with O(1) metadata.  Mid-run a pod JOINS (its links are
gated by ping phases — Algorithm 2), and another pod CRASHES SILENTLY
(Algorithm 3 retries, then abandons its links).  Loss keeps dropping,
replicas stay close, and the happens-before oracle certifies zero causal
violations and zero double-deliveries over the whole run.

    PYTHONPATH=src python examples/elastic_gossip.py
"""

from dataclasses import replace

import jax

from repro.configs import get_arch
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.runtime.gossip import CausalGossipTrainer, GossipConfig


def main():
    cfg = replace(get_arch("yi-6b").smoke(), num_layers=2, d_model=32,
                  d_ff=64, num_heads=2, num_kv_heads=2, head_dim=16,
                  vocab_size=64, compute_dtype="float32",
                  param_dtype="float32")
    dc = DataConfig(vocab_size=64, seq_len=32, global_batch=8)
    tr = CausalGossipTrainer(
        lambda: build_model(cfg, remat="none"), 5,
        GossipConfig(local_steps=2, compress_frac=0.25,
                     ping_timeout=10.0, max_retry=3), dc)

    state = {"round": 0}

    def churn(_, t):
        r = state["round"]
        if r == 4:
            pid = t.join()
            print(f"  >> pod {pid} JOINED (links unsafe until ping phase)")
        if r == 8:
            t.leave(2, graceful=False)
            print("  >> pod 2 CRASHED silently (Alg. 3 will clean up)")

    for r in range(12):
        state["round"] = r
        tr.run_rounds(1, churn=churn)
        print(f"round {r:2d}  mean_loss={tr.mean_loss():.4f}  "
              f"drift={tr.replica_drift():.4f}  "
              f"pods={[p.pid for p in tr.pods.values() if p.alive]}")

    rep = tr.causal_report()
    print("\nhappens-before oracle:", rep.summary())
    assert rep.causal_ok and not rep.double_deliveries
    print("PASS: causal order held through join + silent crash; "
          f"final mean loss {tr.mean_loss():.4f}")


if __name__ == "__main__":
    main()
